//! KKT conditions as a root mapping (paper Eq. 6, Appendix A "Quadratic
//! programming") for the QP
//!
//! ```text
//!   argmin_z ½zᵀQz + cᵀz   s.t.  Ez = d,  Mz ≤ h
//! ```
//!
//! with x = (z, ν, λ) grouping primal and dual variables and differentiable
//! parameters θ = (c ‖ d ‖ h). This recovers OptNet [6] as a special case;
//! no manual derivation is needed beyond writing F itself.

use crate::diff::spec::RootMap;
use crate::linalg::mat::Mat;

/// QP KKT mapping. Matrices are fixed per instance; θ = (c, d, h).
pub struct QpKktMapping {
    pub q: Mat, // p×p symmetric PSD
    pub e: Mat, // q_e×p
    pub m: Mat, // r×p
}

impl QpKktMapping {
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.q.rows, self.e.rows, self.m.rows)
    }

    fn split_x<'a>(&self, x: &'a [f64]) -> (&'a [f64], &'a [f64], &'a [f64]) {
        let (p, qe, _r) = self.dims();
        let (z, rest) = x.split_at(p);
        let (nu, lam) = rest.split_at(qe);
        (z, nu, lam)
    }

    fn split_theta<'a>(&self, t: &'a [f64]) -> (&'a [f64], &'a [f64], &'a [f64]) {
        let (p, qe, _r) = self.dims();
        let (c, rest) = t.split_at(p);
        let (d, h) = rest.split_at(qe);
        (c, d, h)
    }
}

impl RootMap for QpKktMapping {
    fn dim_x(&self) -> usize {
        let (p, qe, r) = self.dims();
        p + qe + r
    }
    fn dim_theta(&self) -> usize {
        let (p, qe, r) = self.dims();
        p + qe + r
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let (p, qe, r) = self.dims();
        let (z, nu, lam) = self.split_x(x);
        let (c, d, h) = self.split_theta(theta);
        // stationarity: Qz + c + Eᵀν + Mᵀλ
        let qz = self.q.matvec(z);
        let etnu = self.e.matvec_t(nu);
        let mtlam = self.m.matvec_t(lam);
        for i in 0..p {
            out[i] = qz[i] + c[i] + etnu[i] + mtlam[i];
        }
        // primal feasibility (equality): Ez − d
        let ez = self.e.matvec(z);
        for i in 0..qe {
            out[p + i] = ez[i] - d[i];
        }
        // complementary slackness: λ∘(Mz − h)
        let mz = self.m.matvec(z);
        for i in 0..r {
            out[p + qe + i] = lam[i] * (mz[i] - h[i]);
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (p, qe, r) = self.dims();
        let (z, _nu, lam) = self.split_x(x);
        let (_c, _d, h) = self.split_theta(theta);
        let (dz, rest) = v.split_at(p);
        let (dnu, dlam) = rest.split_at(qe);
        let qdz = self.q.matvec(dz);
        let etdnu = self.e.matvec_t(dnu);
        let mtdlam = self.m.matvec_t(dlam);
        for i in 0..p {
            out[i] = qdz[i] + etdnu[i] + mtdlam[i];
        }
        let edz = self.e.matvec(dz);
        out[p..p + qe].copy_from_slice(&edz);
        let mz = self.m.matvec(z);
        let mdz = self.m.matvec(dz);
        for i in 0..r {
            out[p + qe + i] = dlam[i] * (mz[i] - h[i]) + lam[i] * mdz[i];
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (p, qe, r) = self.dims();
        let (z, _nu, lam) = self.split_x(x);
        let (_c, _d, h) = self.split_theta(theta);
        let (u1, rest) = u.split_at(p);
        let (u2, u3) = rest.split_at(qe);
        // z-block: Qᵀu1 + Eᵀu2 + Mᵀ(λ∘u3)
        let qu = self.q.matvec_t(u1);
        let etu = self.e.matvec_t(u2);
        let lu3: Vec<f64> = (0..r).map(|i| lam[i] * u3[i]).collect();
        let mtu = self.m.matvec_t(&lu3);
        for i in 0..p {
            out[i] = qu[i] + etu[i] + mtu[i];
        }
        // ν-block: E u1
        let eu = self.e.matvec(u1);
        out[p..p + qe].copy_from_slice(&eu);
        // λ-block: M u1 + (Mz − h)∘u3
        let mu = self.m.matvec(u1);
        let mz = self.m.matvec(z);
        for i in 0..r {
            out[p + qe + i] = mu[i] + (mz[i] - h[i]) * u3[i];
        }
    }
    fn jvp_theta(&self, x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (p, qe, r) = self.dims();
        let (_z, _nu, lam) = self.split_x(x);
        let (dc, rest) = v.split_at(p);
        let (dd, dh) = rest.split_at(qe);
        out[..p].copy_from_slice(dc);
        for i in 0..qe {
            out[p + i] = -dd[i];
        }
        for i in 0..r {
            out[p + qe + i] = -lam[i] * dh[i];
        }
    }
    fn vjp_theta(&self, x: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (p, qe, r) = self.dims();
        let (_z, _nu, lam) = self.split_x(x);
        let (u1, rest) = u.split_at(p);
        let (u2, u3) = rest.split_at(qe);
        out[..p].copy_from_slice(u1);
        for i in 0..qe {
            out[p + i] = -u2[i];
        }
        for i in 0..r {
            out[p + qe + i] = -lam[i] * u3[i];
        }
    }
}

/// Solve an equality-constrained QP exactly via the saddle system (paper
/// Eq. 16). Returns (z, ν).
pub fn solve_eq_qp(q: &Mat, e: &Mat, c: &[f64], d: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let p = q.rows;
    let qe = e.rows;
    let n = p + qe;
    let mut kkt = Mat::zeros(n, n);
    for i in 0..p {
        for j in 0..p {
            *kkt.at_mut(i, j) = q.at(i, j);
        }
        for j in 0..qe {
            *kkt.at_mut(i, p + j) = e.at(j, i);
            *kkt.at_mut(p + j, i) = e.at(j, i);
        }
    }
    let mut rhs = vec![0.0; n];
    for i in 0..p {
        rhs[i] = -c[i];
    }
    for i in 0..qe {
        rhs[p + i] = d[i];
    }
    let lu = crate::linalg::lu::Lu::factor(&kkt).expect("KKT system singular");
    let sol = lu.solve(&rhs);
    (sol[..p].to_vec(), sol[p..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::jacobian_via_root;
    use crate::util::rng::Rng;

    /// Equality-constrained QP: closed-form solution map is linear in θ, so
    /// the implicit Jacobian must match finite differences of the solver.
    #[test]
    fn eq_qp_jacobian_matches_fd() {
        let mut rng = Rng::new(1);
        let p = 4;
        let qe = 2;
        let q = Mat::randn(p + 2, p, &mut rng).gram().plus_diag(1.0);
        let e = Mat::randn(qe, p, &mut rng);
        let mapping = QpKktMapping { q: q.clone(), e: e.clone(), m: Mat::zeros(0, p) };

        let c0 = rng.normal_vec(p);
        let d0 = rng.normal_vec(qe);
        let theta: Vec<f64> = c0.iter().chain(&d0).cloned().collect();
        let (z, nu) = solve_eq_qp(&q, &e, &c0, &d0);
        let x: Vec<f64> = z.iter().chain(&nu).cloned().collect();

        // residual must vanish
        let f = mapping.eval_vec(&x, &theta);
        assert!(crate::linalg::vecops::norm2(&f) < 1e-9);

        let jac = jacobian_via_root(&mapping, &x, &theta);
        // FD of the solver w.r.t. θ (z-part rows only)
        let h = 1e-6;
        for j in 0..theta.len() {
            let mut tp = theta.clone();
            tp[j] += h;
            let (zp, nup) = solve_eq_qp(&q, &e, &tp[..p], &tp[p..]);
            let mut tm = theta.clone();
            tm[j] -= h;
            let (zm, num) = solve_eq_qp(&q, &e, &tm[..p], &tm[p..]);
            for i in 0..p {
                let fd = (zp[i] - zm[i]) / (2.0 * h);
                assert!((jac.at(i, j) - fd).abs() < 1e-5, "z ({i},{j}): {} vs {fd}", jac.at(i, j));
            }
            for i in 0..qe {
                let fd = (nup[i] - num[i]) / (2.0 * h);
                assert!((jac.at(p + i, j) - fd).abs() < 1e-5, "ν ({i},{j})");
            }
        }
    }

    /// Inequality QP with known active set: minimize ½(z−1)² s.t. z ≤ 0
    /// (active) → z* = 0, λ* = 1; sensitivity w.r.t. h: z*(h) = h → dz/dh = 1.
    #[test]
    fn active_inequality_sensitivity() {
        let q = Mat::eye(1);
        let e = Mat::zeros(0, 1);
        let m = Mat::eye(1);
        let mapping = QpKktMapping { q, e, m };
        // θ = (c, h) = (−1, 0): f = ½z² − z, constraint z ≤ 0.
        let theta = vec![-1.0, 0.0];
        let x = vec![0.0, 1.0]; // z = 0, λ = 1
        let f = mapping.eval_vec(&x, &theta);
        assert!(crate::linalg::vecops::norm2(&f) < 1e-12);
        let jac = jacobian_via_root(&mapping, &x, &theta);
        // dz/dh = 1 (constraint active, solution tracks the boundary)
        assert!((jac.at(0, 1) - 1.0).abs() < 1e-6, "dz/dh = {}", jac.at(0, 1));
        // dz/dc = 0 (pinned at the boundary)
        assert!(jac.at(0, 0).abs() < 1e-6);
    }

    #[test]
    fn jvp_vjp_adjoint_identity() {
        let mut rng = Rng::new(2);
        let (p, qe, r) = (3, 1, 2);
        let q = Mat::randn(p + 1, p, &mut rng).gram().plus_diag(0.5);
        let e = Mat::randn(qe, p, &mut rng);
        let m = Mat::randn(r, p, &mut rng);
        let mapping = QpKktMapping { q, e, m };
        let x = rng.normal_vec(p + qe + r);
        let theta = rng.normal_vec(p + qe + r);
        let v = rng.normal_vec(p + qe + r);
        let u = rng.normal_vec(p + qe + r);
        let mut jv = vec![0.0; p + qe + r];
        mapping.jvp_x(&x, &theta, &v, &mut jv);
        let mut vj = vec![0.0; p + qe + r];
        mapping.vjp_x(&x, &theta, &u, &mut vj);
        let lhs = crate::linalg::vecops::dot(&u, &jv);
        let rhs = crate::linalg::vecops::dot(&vj, &v);
        assert!((lhs - rhs).abs() < 1e-9);
        // θ side
        let vt = rng.normal_vec(p + qe + r);
        let mut jt = vec![0.0; p + qe + r];
        mapping.jvp_theta(&x, &theta, &vt, &mut jt);
        let mut vjt = vec![0.0; p + qe + r];
        mapping.vjp_theta(&x, &theta, &u, &mut vjt);
        let lhs = crate::linalg::vecops::dot(&u, &jt);
        let rhs = crate::linalg::vecops::dot(&vjt, &vt);
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn jacobians_match_fd_generic_point() {
        let mut rng = Rng::new(3);
        let (p, qe, r) = (3, 1, 2);
        let q = Mat::randn(p + 1, p, &mut rng).gram().plus_diag(0.5);
        let e = Mat::randn(qe, p, &mut rng);
        let m = Mat::randn(r, p, &mut rng);
        let mapping = QpKktMapping { q, e, m };
        let x = rng.normal_vec(p + qe + r);
        let theta = rng.normal_vec(p + qe + r);
        let v = rng.normal_vec(p + qe + r);
        let mut jv = vec![0.0; p + qe + r];
        mapping.jvp_x(&x, &theta, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|xx| mapping.eval_vec(xx, &theta), &x, &v, 1e-7);
        for i in 0..jv.len() {
            assert!((jv[i] - fd[i]).abs() < 1e-6);
        }
        let mut jt = vec![0.0; p + qe + r];
        mapping.jvp_theta(&x, &theta, &v, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|tt| mapping.eval_vec(&x, tt), &theta, &v, 1e-7);
        for i in 0..jt.len() {
            assert!((jt[i] - fd[i]).abs() < 1e-6);
        }
    }
}
