//! Newton fixed point (paper Appendix A, Eq. 14):
//! T(x, θ) = x − η[∂₁G(x, θ)]⁻¹G(x, θ) for a root x of G(·, θ).
//!
//! At the root, ∂₁T = (1−η)I so A = ηI, and B = −η[∂₁G]⁻¹∂₂G — the implicit
//! system reduces to the one obtained by differentiating G directly (the
//! paper's remark), which the tests verify.

use crate::diff::spec::{FixedPointMap, RootMap};
use crate::linalg::op::FnOp;
use crate::linalg::solve::{self, LinearSolveConfig};

/// Newton fixed point built on any root mapping G.
pub struct NewtonFixedPoint<G: RootMap> {
    pub g: G,
    pub eta: f64,
    pub cfg: LinearSolveConfig,
}

impl<G: RootMap> NewtonFixedPoint<G> {
    pub fn new(g: G, eta: f64) -> Self {
        NewtonFixedPoint { g, eta, cfg: LinearSolveConfig::default() }
    }

    /// Solve ∂₁G(x, θ) w = rhs.
    fn solve_jac(&self, x: &[f64], theta: &[f64], rhs: &[f64]) -> Vec<f64> {
        let d = self.g.dim_x();
        let op = FnOp {
            d,
            fwd: |v: &[f64], y: &mut [f64]| self.g.jvp_x(x, theta, v, y),
            tr: |u: &[f64], y: &mut [f64]| self.g.vjp_x(x, theta, u, y),
            symmetric: self.g.a_symmetric(),
        };
        let mut w = vec![0.0; d];
        solve::solve(&op, rhs, &mut w, &self.cfg);
        w
    }

    /// Solve ∂₁G(x, θ)ᵀ w = rhs.
    fn solve_jac_t(&self, x: &[f64], theta: &[f64], rhs: &[f64]) -> Vec<f64> {
        let d = self.g.dim_x();
        let op = FnOp {
            d,
            fwd: |v: &[f64], y: &mut [f64]| self.g.jvp_x(x, theta, v, y),
            tr: |u: &[f64], y: &mut [f64]| self.g.vjp_x(x, theta, u, y),
            symmetric: self.g.a_symmetric(),
        };
        let mut w = vec![0.0; d];
        solve::solve_t(&op, rhs, &mut w, &self.cfg);
        w
    }
}

impl<G: RootMap> FixedPointMap for NewtonFixedPoint<G> {
    fn dim_x(&self) -> usize {
        self.g.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.g.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let gx = self.g.eval_vec(x, theta);
        let step = self.solve_jac(x, theta, &gx);
        for i in 0..x.len() {
            out[i] = x[i] - self.eta * step[i];
        }
    }
    // Derivative oracles are evaluated AT THE ROOT (G = 0), where the paper's
    // simplification holds: ∂₁T = (1−η)I, ∂₂T = −η[∂₁G]⁻¹∂₂G.
    fn jvp_x(&self, _x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..v.len() {
            out[i] = (1.0 - self.eta) * v[i];
        }
    }
    fn vjp_x(&self, _x: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        for i in 0..u.len() {
            out[i] = (1.0 - self.eta) * u[i];
        }
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let mut b = vec![0.0; self.g.dim_x()];
        self.g.jvp_theta(x, theta, v, &mut b);
        let w = self.solve_jac(x, theta, &b);
        for i in 0..out.len() {
            out[i] = -self.eta * w[i];
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        // (−η[∂₁G]⁻¹∂₂G)ᵀu = −η ∂₂Gᵀ [∂₁G]⁻ᵀ u
        let w = self.solve_jac_t(x, theta, u);
        self.g.vjp_theta(x, theta, &w, out);
        for o in out.iter_mut() {
            *o *= -self.eta;
        }
    }
    fn a_symmetric(&self) -> bool {
        true // A = ηI
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::jacobian_via_root;
    use crate::diff::spec::{ClosureRoot, FixedPointResidual};
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::mappings::stationary::StationaryMapping;
    use crate::util::rng::Rng;

    fn quad_mapping(seed: u64) -> (StationaryMapping<QuadObjective>, Vec<f64>, Vec<f64>, Mat) {
        let mut rng = Rng::new(seed);
        let d = 5;
        let n = 3;
        let q = Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(d, n, &mut rng);
        let c = rng.normal_vec(d);
        let theta = rng.normal_vec(n);
        let ch = crate::linalg::chol::Cholesky::factor(&q).unwrap();
        let rt = r.matvec(&theta);
        let rhs: Vec<f64> = rt.iter().zip(&c).map(|(a, b)| -(a + b)).collect();
        let x_star = ch.solve(&rhs);
        let jac_true = ch.solve_mat(&r.map(|v| -v));
        (StationaryMapping::new(QuadObjective { q, r, c }), theta, x_star, jac_true)
    }

    #[test]
    fn newton_converges_in_one_step_on_quadratic() {
        let (m, theta, x_star, _) = quad_mapping(1);
        let newton = NewtonFixedPoint::new(m, 1.0);
        let mut rng = Rng::new(2);
        let x0 = rng.normal_vec(5);
        let x1 = newton.eval_vec(&x0, &theta);
        for i in 0..5 {
            assert!((x1[i] - x_star[i]).abs() < 1e-6, "{} vs {}", x1[i], x_star[i]);
        }
    }

    #[test]
    fn newton_fixed_point_recovers_direct_jacobian() {
        for eta in [0.5, 1.0] {
            let (m, theta, x_star, jac_true) = quad_mapping(3);
            let newton = NewtonFixedPoint::new(m, eta);
            let res = FixedPointResidual(newton);
            let jac = jacobian_via_root(&res, &x_star, &theta);
            for i in 0..5 {
                for j in 0..3 {
                    assert!(
                        (jac.at(i, j) - jac_true.at(i, j)).abs() < 1e-6,
                        "eta={eta} ({i},{j}): {} vs {}",
                        jac.at(i, j),
                        jac_true.at(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn newton_for_scalar_root_finding() {
        // G(x, θ) = x² − θ; Newton root-finding map; ∂x* = 1/(2√θ).
        let g = ClosureRoot {
            d: 1,
            n: 1,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = x[0] * x[0] - th[0];
            },
            symmetric: false,
        };
        let newton = NewtonFixedPoint::new(g, 1.0);
        let theta = [9.0];
        // iterate the Newton map to find the root
        let mut x = vec![1.0];
        for _ in 0..50 {
            x = newton.eval_vec(&x, &theta);
        }
        assert!((x[0] - 3.0).abs() < 1e-10);
        let res = FixedPointResidual(newton);
        let jac = jacobian_via_root(&res, &x, &theta);
        assert!((jac.at(0, 0) - 1.0 / 6.0).abs() < 1e-5);
    }
}
