//! Proximal-gradient (Eq. 7), projected-gradient (Eq. 9) and block
//! proximal-gradient (Eq. 15) fixed points.
//!
//! θ is the concatenation [θ_f ‖ θ_g]: the smooth objective's parameters
//! followed by the prox/projection parameters (the paper's Figure 2 unpacks
//! the same way).

use super::objective::Objective;
use crate::diff::spec::FixedPointMap;
use crate::linalg::mat::Mat;
use crate::proj::Projection;
use crate::prox::Prox;

/// Shared structure of the batched ∂₁T·V products for the prox-/proj-grad
/// fixed points: ONE batched HVP for the expensive Hessian block, then a
/// per-column elementwise prox/projection Jacobian (`map_col`, O(d) each).
fn batched_pre_jvp<O: Objective>(
    obj: &O,
    eta: f64,
    x: &[f64],
    tf: &[f64],
    v: &Mat,
    map_col: impl FnMut(&[f64], &mut [f64]),
    out: &mut Mat,
) {
    let d = x.len();
    let mut hv = Mat::zeros(v.rows, v.cols);
    obj.hvp_xx_batch(x, tf, v, &mut hv);
    for (h, vi) in hv.data.iter_mut().zip(v.data.iter()) {
        *h = *vi - eta * *h; // dy = v − η·Hv
    }
    crate::linalg::op::batch_cols(d, d, &hv, out, map_col);
}

/// Transposed counterpart: per-column prox/projection VJP first (`map_col`),
/// then one batched HVP over the whole block, out = W − η·H·W.
fn batched_post_vjp<O: Objective>(
    obj: &O,
    eta: f64,
    x: &[f64],
    tf: &[f64],
    u: &Mat,
    map_col: impl FnMut(&[f64], &mut [f64]),
    out: &mut Mat,
) {
    let d = x.len();
    assert_eq!((out.rows, out.cols), (d, u.cols), "batched vjp output must be d × k");
    let mut w = Mat::zeros(d, u.cols);
    crate::linalg::op::batch_cols(d, d, u, &mut w, map_col);
    let mut hw = Mat::zeros(d, u.cols);
    obj.hvp_xx_batch(x, tf, &w, &mut hw);
    for i in 0..out.data.len() {
        out.data[i] = w.data[i] - eta * hw.data[i];
    }
}

/// T(x, θ) = prox_{ηg}(x − η∇₁f(x, θ_f), θ_g).
pub struct ProxGradFixedPoint<O: Objective, P: Prox> {
    pub obj: O,
    pub prox: P,
    pub eta: f64,
}

impl<O: Objective, P: Prox> ProxGradFixedPoint<O, P> {
    pub fn new(obj: O, prox: P, eta: f64) -> Self {
        assert_eq!(obj.dim_x(), prox.dim());
        ProxGradFixedPoint { obj, prox, eta }
    }

    fn split<'a>(&self, theta: &'a [f64]) -> (&'a [f64], &'a [f64]) {
        theta.split_at(self.obj.dim_theta())
    }

    /// y = x − η ∇₁f(x, θ_f).
    fn pre_step(&self, x: &[f64], theta_f: &[f64]) -> Vec<f64> {
        let mut g = vec![0.0; x.len()];
        self.obj.grad_x(x, theta_f, &mut g);
        (0..x.len()).map(|i| x[i] - self.eta * g[i]).collect()
    }
}

impl<O: Objective, P: Prox> FixedPointMap for ProxGradFixedPoint<O, P> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta() + self.prox.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        self.prox.prox(&y, tg, self.eta, out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        let mut hv = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, v, &mut hv);
        let dy: Vec<f64> = (0..x.len()).map(|i| v[i] - self.eta * hv[i]).collect();
        self.prox.jvp_y(&y, tg, self.eta, &dy, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        let mut w = vec![0.0; x.len()];
        self.prox.vjp_y(&y, tg, self.eta, u, &mut w);
        let mut hw = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, &w, &mut hw); // Hessian symmetric
        for i in 0..x.len() {
            out[i] = w[i] - self.eta * hw[i];
        }
    }
    // Batched ∂₁T products: one batched HVP for the Hessian block, the
    // separable prox Jacobians stay per-column (elementwise, O(d) each).
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        batched_pre_jvp(&self.obj, self.eta, x, tf, v, |dy, o| {
            self.prox.jvp_y(&y, tg, self.eta, dy, o)
        }, out);
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        batched_post_vjp(&self.obj, self.eta, x, tf, u, |uc, o| {
            self.prox.vjp_y(&y, tg, self.eta, uc, o)
        }, out);
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.split(theta);
        let (vf, vg) = v.split_at(self.obj.dim_theta());
        let y = self.pre_step(x, tf);
        // ∂_θf branch through y
        let mut cross = vec![0.0; x.len()];
        self.obj.jvp_x_theta(x, tf, vf, &mut cross);
        let dy: Vec<f64> = cross.iter().map(|c| -self.eta * c).collect();
        self.prox.jvp_y(&y, tg, self.eta, &dy, out);
        // ∂_θg branch directly through the prox
        if self.prox.dim_theta() > 0 {
            let mut dprox = vec![0.0; x.len()];
            self.prox.jvp_theta(&y, tg, self.eta, vg, &mut dprox);
            for i in 0..x.len() {
                out[i] += dprox[i];
            }
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.split(theta);
        let y = self.pre_step(x, tf);
        let nf = self.obj.dim_theta();
        let mut w = vec![0.0; x.len()];
        self.prox.vjp_y(&y, tg, self.eta, u, &mut w);
        // θ_f part: −η (∂₂∇₁f)ᵀ w
        let mut vf = vec![0.0; nf];
        self.obj.vjp_x_theta(x, tf, &w, &mut vf);
        for (o, v) in out[..nf].iter_mut().zip(&vf) {
            *o = -self.eta * v;
        }
        // θ_g part: ∂_θ proxᵀ u
        if self.prox.dim_theta() > 0 {
            self.prox.vjp_theta(&y, tg, self.eta, u, &mut out[nf..]);
        }
    }
}

/// T(x, θ) = proj_C(x − η∇₁f(x, θ_f), θ_proj) — Eq. 9, the special case
/// g = indicator of C(θ).
pub struct ProjGradFixedPoint<O: Objective, P: Projection> {
    pub obj: O,
    pub proj: P,
    pub eta: f64,
}

impl<O: Objective, P: Projection> ProjGradFixedPoint<O, P> {
    pub fn new(obj: O, proj: P, eta: f64) -> Self {
        assert_eq!(obj.dim_x(), proj.dim());
        ProjGradFixedPoint { obj, proj, eta }
    }
    fn split<'a>(&self, theta: &'a [f64]) -> (&'a [f64], &'a [f64]) {
        theta.split_at(self.obj.dim_theta())
    }
    fn pre_step(&self, x: &[f64], theta_f: &[f64]) -> Vec<f64> {
        let mut g = vec![0.0; x.len()];
        self.obj.grad_x(x, theta_f, &mut g);
        (0..x.len()).map(|i| x[i] - self.eta * g[i]).collect()
    }
}

impl<O: Objective, P: Projection> FixedPointMap for ProjGradFixedPoint<O, P> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta() + self.proj.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        self.proj.project(&y, tp, out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        let mut hv = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, v, &mut hv);
        let dy: Vec<f64> = (0..x.len()).map(|i| v[i] - self.eta * hv[i]).collect();
        self.proj.jvp_y(&y, tp, &dy, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        let mut w = vec![0.0; x.len()];
        self.proj.vjp_y(&y, tp, u, &mut w);
        let mut hw = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, &w, &mut hw);
        for i in 0..x.len() {
            out[i] = w[i] - self.eta * hw[i];
        }
    }
    // Batched ∂₁T products — same shared structure as ProxGradFixedPoint,
    // with the projection Jacobian as the per-column map.
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        batched_pre_jvp(&self.obj, self.eta, x, tf, v, |dy, o| self.proj.jvp_y(&y, tp, dy, o), out);
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        batched_post_vjp(&self.obj, self.eta, x, tf, u, |uc, o| self.proj.vjp_y(&y, tp, uc, o), out);
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (tf, tp) = self.split(theta);
        let (vf, vp) = v.split_at(self.obj.dim_theta());
        let y = self.pre_step(x, tf);
        let mut cross = vec![0.0; x.len()];
        self.obj.jvp_x_theta(x, tf, vf, &mut cross);
        let dy: Vec<f64> = cross.iter().map(|c| -self.eta * c).collect();
        self.proj.jvp_y(&y, tp, &dy, out);
        if self.proj.dim_theta() > 0 {
            let mut dp = vec![0.0; x.len()];
            self.proj.jvp_theta(&y, tp, vp, &mut dp);
            for i in 0..x.len() {
                out[i] += dp[i];
            }
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (tf, tp) = self.split(theta);
        let y = self.pre_step(x, tf);
        let nf = self.obj.dim_theta();
        let mut w = vec![0.0; x.len()];
        self.proj.vjp_y(&y, tp, u, &mut w);
        let mut vf = vec![0.0; nf];
        self.obj.vjp_x_theta(x, tf, &w, &mut vf);
        for (o, v) in out[..nf].iter_mut().zip(&vf) {
            *o = -self.eta * v;
        }
        if self.proj.dim_theta() > 0 {
            self.proj.vjp_theta(&y, tp, u, &mut out[nf..]);
        }
    }
}

/// Block proximal-gradient fixed point (Eq. 15): per-block step sizes η_j,
/// each block passed through the same prox family. Equal η's reduce to the
/// plain proximal-gradient fixed point (verified in tests).
pub struct BlockProxGradFixedPoint<O: Objective, P: Prox> {
    pub obj: O,
    pub prox: P,
    /// (start, end, η) per block; blocks must tile 0..d.
    pub blocks: Vec<(usize, usize, f64)>,
}

impl<O: Objective, P: Prox> BlockProxGradFixedPoint<O, P> {
    fn theta_split<'a>(&self, theta: &'a [f64]) -> (&'a [f64], &'a [f64]) {
        theta.split_at(self.obj.dim_theta())
    }
}

impl<O: Objective, P: Prox> FixedPointMap for BlockProxGradFixedPoint<O, P> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta() + self.prox.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.theta_split(theta);
        let mut g = vec![0.0; x.len()];
        self.obj.grad_x(x, tf, &mut g);
        for &(s, e, eta) in &self.blocks {
            let y: Vec<f64> = (s..e).map(|i| x[i] - eta * g[i]).collect();
            // prox families here are separable, so applying the d-dim prox on
            // a block slice is valid; use a scratch padded vector.
            let mut sub = vec![0.0; e - s];
            block_prox(&self.prox, &y, tg, eta, &mut sub);
            out[s..e].copy_from_slice(&sub);
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.theta_split(theta);
        let mut g = vec![0.0; x.len()];
        self.obj.grad_x(x, tf, &mut g);
        let mut hv = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, v, &mut hv);
        for &(s, e, eta) in &self.blocks {
            let y: Vec<f64> = (s..e).map(|i| x[i] - eta * g[i]).collect();
            let dy: Vec<f64> = (s..e).map(|i| v[i] - eta * hv[i]).collect();
            let mut sub = vec![0.0; e - s];
            block_prox_jvp(&self.prox, &y, tg, eta, &dy, &mut sub);
            out[s..e].copy_from_slice(&sub);
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (tf, tg) = self.theta_split(theta);
        let mut g = vec![0.0; x.len()];
        self.obj.grad_x(x, tf, &mut g);
        // w_j = ∂proxᵀ u per block, then out = w − Hᵀ(η_b w) blockwise.
        let mut w = vec![0.0; x.len()];
        for &(s, e, eta) in &self.blocks {
            let y: Vec<f64> = (s..e).map(|i| x[i] - eta * g[i]).collect();
            let mut sub = vec![0.0; e - s];
            block_prox_jvp(&self.prox, &y, tg, eta, &u[s..e], &mut sub); // symmetric prox Jacobians
            w[s..e].copy_from_slice(&sub);
        }
        let weta: Vec<f64> = {
            let mut t = vec![0.0; x.len()];
            for &(s, e, eta) in &self.blocks {
                for i in s..e {
                    t[i] = eta * w[i];
                }
            }
            t
        };
        let mut hw = vec![0.0; x.len()];
        self.obj.hvp_xx(x, tf, &weta, &mut hw);
        for i in 0..x.len() {
            out[i] = w[i] - hw[i];
        }
    }
}

/// Apply a separable prox family on a block slice.
fn block_prox<P: Prox>(p: &P, y: &[f64], tg: &[f64], eta: f64, out: &mut [f64]) {
    // Separable prox: pad into a full-d vector? The prox implementations in
    // this crate are elementwise/groupwise and accept any length ≥ the slice,
    // so call through a temporary of the slice length.
    p.prox_slice(y, tg, eta, out);
}

fn block_prox_jvp<P: Prox>(p: &P, y: &[f64], tg: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
    p.jvp_y_slice(y, tg, eta, v, out);
}

/// Extension for separable prox operators: operate on arbitrary-length
/// slices (needed by the block fixed point).
pub trait SeparableProx: Prox {
    fn prox_slice(&self, y: &[f64], theta: &[f64], eta: f64, out: &mut [f64]);
    fn jvp_y_slice(&self, y: &[f64], theta: &[f64], eta: f64, v: &[f64], out: &mut [f64]);
}

// All catalog prox families are separable elementwise; default slice impls
// delegate to the elementwise formulas by treating the slice as the whole
// vector (their implementations only use y.len()).
impl<P: Prox> SeparableProx for P {
    fn prox_slice(&self, y: &[f64], theta: &[f64], eta: f64, out: &mut [f64]) {
        self.prox(y, theta, eta, out);
    }
    fn jvp_y_slice(&self, y: &[f64], theta: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        self.jvp_y(y, theta, eta, v, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::{FixedPointMap, FixedPointResidual, RootMap};
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::proj::simplex::SimplexProjection;
    use crate::prox::LassoProx;
    use crate::util::rng::Rng;

    fn random_quad(d: usize, n: usize, seed: u64) -> QuadObjective {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(d, n, &mut rng);
        let c = rng.normal_vec(d);
        QuadObjective { q, r, c }
    }

    fn check_fp_jacobians<T: FixedPointMap>(t: &T, theta: &[f64], seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(t.dim_x());
        // jvp_x vs FD
        let v = rng.normal_vec(t.dim_x());
        let mut jv = vec![0.0; t.dim_x()];
        t.jvp_x(&x, theta, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|xx| t.eval_vec(xx, theta), &x, &v, 1e-7);
        for i in 0..t.dim_x() {
            assert!((jv[i] - fd[i]).abs() < tol, "jvp_x {i}: {} vs {}", jv[i], fd[i]);
        }
        // jvp_theta vs FD
        let vt = rng.normal_vec(t.dim_theta());
        let mut jt = vec![0.0; t.dim_x()];
        t.jvp_theta(&x, theta, &vt, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|tt| t.eval_vec(&x, tt), theta, &vt, 1e-7);
        for i in 0..t.dim_x() {
            assert!((jt[i] - fd[i]).abs() < tol, "jvp_θ {i}: {} vs {}", jt[i], fd[i]);
        }
        // adjoint identities
        let u = rng.normal_vec(t.dim_x());
        let mut vx = vec![0.0; t.dim_x()];
        t.vjp_x(&x, theta, &u, &mut vx);
        let lhs = crate::linalg::vecops::dot(&u, &jv);
        let rhs = crate::linalg::vecops::dot(&vx, &v);
        assert!((lhs - rhs).abs() < 1e-8, "x adjoint: {lhs} vs {rhs}");
        let mut vth = vec![0.0; t.dim_theta()];
        t.vjp_theta(&x, theta, &u, &mut vth);
        let lhs = crate::linalg::vecops::dot(&u, &jt);
        let rhs = crate::linalg::vecops::dot(&vth, &vt);
        assert!((lhs - rhs).abs() < 1e-8, "θ adjoint: {lhs} vs {rhs}");
    }

    #[test]
    fn prox_grad_jacobians_match_fd() {
        let t = ProxGradFixedPoint::new(random_quad(6, 2, 1), LassoProx { d: 6 }, 0.1);
        let theta = [0.4, -0.2, 0.5]; // θ_f ∈ R², θ_g = λ
        check_fp_jacobians(&t, &theta, 2, 1e-5);
    }

    #[test]
    fn proj_grad_jacobians_match_fd() {
        let t = ProjGradFixedPoint::new(random_quad(5, 2, 3), SimplexProjection { d: 5 }, 0.1);
        let theta = [0.3, 0.8];
        check_fp_jacobians(&t, &theta, 4, 1e-5);
    }

    #[test]
    fn batched_fixed_point_products_match_column_loop() {
        let t = ProxGradFixedPoint::new(random_quad(6, 2, 9), LassoProx { d: 6 }, 0.1);
        let theta = [0.4, -0.2, 0.5];
        let mut rng = Rng::new(10);
        let x = rng.normal_vec(6);
        let v = Mat::randn(6, 4, &mut rng);
        let mut fast = Mat::zeros(6, 4);
        t.jvp_x_batch(&x, &theta, &v, &mut fast);
        let mut vc = vec![0.0; 6];
        let mut oc = vec![0.0; 6];
        for j in 0..4 {
            v.col_into(j, &mut vc);
            t.jvp_x(&x, &theta, &vc, &mut oc);
            for i in 0..6 {
                assert!((fast.at(i, j) - oc[i]).abs() < 1e-10);
            }
        }
        let mut fast_t = Mat::zeros(6, 4);
        t.vjp_x_batch(&x, &theta, &v, &mut fast_t);
        for j in 0..4 {
            v.col_into(j, &mut vc);
            t.vjp_x(&x, &theta, &vc, &mut oc);
            for i in 0..6 {
                assert!((fast_t.at(i, j) - oc[i]).abs() < 1e-10);
            }
        }
        let pg = ProjGradFixedPoint::new(random_quad(5, 2, 11), SimplexProjection { d: 5 }, 0.1);
        let theta = [0.3, 0.8];
        let x = rng.normal_vec(5);
        let v = Mat::randn(5, 3, &mut rng);
        let mut fast = Mat::zeros(5, 3);
        pg.jvp_x_batch(&x, &theta, &v, &mut fast);
        let mut vc = vec![0.0; 5];
        let mut oc = vec![0.0; 5];
        for j in 0..3 {
            v.col_into(j, &mut vc);
            pg.jvp_x(&x, &theta, &vc, &mut oc);
            for i in 0..5 {
                assert!((fast.at(i, j) - oc[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn block_equal_etas_reduce_to_prox_grad() {
        let obj = random_quad(6, 2, 5);
        let obj2 = random_quad(6, 2, 5);
        let pg = ProxGradFixedPoint::new(obj, LassoProx { d: 6 }, 0.2);
        let bl = BlockProxGradFixedPoint {
            obj: obj2,
            prox: LassoProx { d: 6 },
            blocks: vec![(0, 3, 0.2), (3, 6, 0.2)],
        };
        let theta = [0.1, 0.2, 0.3];
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(6);
        let a = pg.eval_vec(&x, &theta);
        let b = bl.eval_vec(&x, &theta);
        for i in 0..6 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
        let v = rng.normal_vec(6);
        let mut ja = vec![0.0; 6];
        pg.jvp_x(&x, &theta, &v, &mut ja);
        let mut jb = vec![0.0; 6];
        bl.jvp_x(&x, &theta, &v, &mut jb);
        for i in 0..6 {
            assert!((ja[i] - jb[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn lasso_fixed_point_root_identity() {
        // At the lasso solution the prox-grad map is a fixed point; verify on
        // a tiny problem solved by iterating T.
        let obj = random_quad(4, 1, 7);
        let t = ProxGradFixedPoint::new(obj, LassoProx { d: 4 }, 0.05);
        let theta = [0.0, 0.3];
        let mut x = vec![0.0; 4];
        for _ in 0..4000 {
            let nx = t.eval_vec(&x, &theta);
            x = nx;
        }
        let res = FixedPointResidual(t);
        let f = res.eval_vec(&x, &theta);
        assert!(crate::linalg::vecops::norm2(&f) < 1e-10);
    }
}
