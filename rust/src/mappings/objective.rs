//! The objective oracle f(x, θ): what a stationary/fixed-point mapping needs
//! from the inner problem — ∇₁f plus three Jacobian products of ∇₁f.
//! Models implement these analytically; `FnObjective` derives everything
//! from a value closure by finite differences (the "just write f" path); and
//! tests cross-check the two.

use crate::ad::num_grad;
use crate::diff::spec::batch_cols;
use crate::linalg::mat::Mat;

/// Twice-differentiable objective f : R^d × R^n → R.
pub trait Objective {
    fn dim_x(&self) -> usize;
    fn dim_theta(&self) -> usize;

    /// f(x, θ).
    fn value(&self, x: &[f64], theta: &[f64]) -> f64;

    /// out = ∇₁f(x, θ).
    fn grad_x(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let g = num_grad::grad_fd(|xx| self.value(xx, theta), x, 1e-6);
        out.copy_from_slice(&g);
    }

    /// out = ∇₁²f(x, θ) · v (Hessian-vector product; symmetric).
    fn hvp_xx(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|xx| self.grad_x_vec(xx, theta), x, v, 1e-5);
        out.copy_from_slice(&r);
    }

    /// out = ∂₂∇₁f(x, θ) · v  (v ∈ R^n, out ∈ R^d).
    fn jvp_x_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|tt| self.grad_x_vec(x, tt), theta, v, 1e-5);
        out.copy_from_slice(&r);
    }

    /// out = (∂₂∇₁f(x, θ))ᵀ · u  (u ∈ R^d, out ∈ R^n).
    fn vjp_x_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|tt| self.grad_x_vec(x, tt), theta, u, 1e-5);
        out.copy_from_slice(&r);
    }

    /// out = ∇₁²f(x, θ) · V columnwise (V, out ∈ R^{d×k}). Default loops
    /// [`Objective::hvp_xx`]; models with a materialized Hessian/Gram matrix
    /// override with a single GEMM.
    fn hvp_xx_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_x(), v, out, |vc, oc| self.hvp_xx(x, theta, vc, oc));
    }

    /// out = ∂₂∇₁f(x, θ) · V (V ∈ R^{n×k} → out ∈ R^{d×k}).
    fn jvp_x_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_theta(), self.dim_x(), v, out, |vc, oc| {
            self.jvp_x_theta(x, theta, vc, oc)
        });
    }

    /// out = (∂₂∇₁f(x, θ))ᵀ · U (U ∈ R^{d×k} → out ∈ R^{n×k}).
    fn vjp_x_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_theta(), u, out, |uc, oc| {
            self.vjp_x_theta(x, theta, uc, oc)
        });
    }

    fn grad_x_vec(&self, x: &[f64], theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim_x()];
        self.grad_x(x, theta, &mut out);
        out
    }
}

/// Objective from a plain value closure; all derivatives via FD defaults.
pub struct FnObjective<F: Fn(&[f64], &[f64]) -> f64> {
    pub d: usize,
    pub n: usize,
    pub f: F,
}

impl<F: Fn(&[f64], &[f64]) -> f64> Objective for FnObjective<F> {
    fn dim_x(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        self.n
    }
    fn value(&self, x: &[f64], theta: &[f64]) -> f64 {
        (self.f)(x, theta)
    }
}

/// A quadratic test objective f = ½xᵀQx + xᵀRθ + cᵀx with analytic oracles —
/// used across the mapping tests as a ground-truth instance.
pub struct QuadObjective {
    pub q: crate::linalg::Mat,   // d×d symmetric
    pub r: crate::linalg::Mat,   // d×n
    pub c: Vec<f64>,             // d
}

impl Objective for QuadObjective {
    fn dim_x(&self) -> usize {
        self.q.rows
    }
    fn dim_theta(&self) -> usize {
        self.r.cols
    }
    fn value(&self, x: &[f64], theta: &[f64]) -> f64 {
        let qx = self.q.matvec(x);
        let rt = self.r.matvec(theta);
        0.5 * crate::linalg::vecops::dot(x, &qx)
            + crate::linalg::vecops::dot(x, &rt)
            + crate::linalg::vecops::dot(x, &self.c)
    }
    fn grad_x(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.q.matvec_into(x, out);
        let rt = self.r.matvec(theta);
        for i in 0..out.len() {
            out[i] += rt[i] + self.c[i];
        }
    }
    fn hvp_xx(&self, _x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.q.matvec_into(v, out);
    }
    fn jvp_x_theta(&self, _x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.r.matvec_into(v, out);
    }
    fn vjp_x_theta(&self, _x: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.r.matvec_t_into(u, out);
    }
    // Batched oracles: one packed GEMM per block instead of k matvecs.
    fn hvp_xx_batch(&self, _x: &[f64], _theta: &[f64], v: &Mat, out: &mut Mat) {
        self.q.matmul_into(v, out);
    }
    fn jvp_x_theta_batch(&self, _x: &[f64], _theta: &[f64], v: &Mat, out: &mut Mat) {
        self.r.matmul_into(v, out);
    }
    fn vjp_x_theta_batch(&self, _x: &[f64], _theta: &[f64], u: &Mat, out: &mut Mat) {
        self.r.t_matmul_into(u, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    pub fn random_quad(d: usize, n: usize, seed: u64) -> QuadObjective {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(d, n, &mut rng);
        let c = rng.normal_vec(d);
        QuadObjective { q, r, c }
    }

    #[test]
    fn analytic_oracles_match_fd_defaults() {
        let quad = random_quad(5, 3, 1);
        let fnobj = FnObjective { d: 5, n: 3, f: |x: &[f64], t: &[f64]| quad.value(x, t) };
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(5);
        let th = rng.normal_vec(3);
        // grad
        let ga = quad.grad_x_vec(&x, &th);
        let gf = fnobj.grad_x_vec(&x, &th);
        for i in 0..5 {
            assert!((ga[i] - gf[i]).abs() < 1e-4, "{} vs {}", ga[i], gf[i]);
        }
        // hvp
        let v = rng.normal_vec(5);
        let mut ha = vec![0.0; 5];
        quad.hvp_xx(&x, &th, &v, &mut ha);
        let mut hf = vec![0.0; 5];
        fnobj.hvp_xx(&x, &th, &v, &mut hf);
        for i in 0..5 {
            assert!((ha[i] - hf[i]).abs() < 1e-2, "{} vs {}", ha[i], hf[i]);
        }
        // cross products
        let vt = rng.normal_vec(3);
        let mut ca = vec![0.0; 5];
        quad.jvp_x_theta(&x, &th, &vt, &mut ca);
        let mut cf = vec![0.0; 5];
        fnobj.jvp_x_theta(&x, &th, &vt, &mut cf);
        for i in 0..5 {
            assert!((ca[i] - cf[i]).abs() < 1e-2);
        }
        let u = rng.normal_vec(5);
        let mut va = vec![0.0; 3];
        quad.vjp_x_theta(&x, &th, &u, &mut va);
        let mut vf = vec![0.0; 3];
        fnobj.vjp_x_theta(&x, &th, &u, &mut vf);
        for i in 0..3 {
            assert!((va[i] - vf[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn quad_batched_oracles_match_column_loop() {
        let quad = random_quad(6, 4, 9);
        let mut rng = Rng::new(10);
        let x = rng.normal_vec(6);
        let th = rng.normal_vec(4);
        let v = Mat::randn(6, 3, &mut rng);
        // GEMM override vs the FnObjective default (column loop over FD-free
        // analytic hvp via a wrapper with no batch override).
        let mut fast = Mat::zeros(6, 3);
        quad.hvp_xx_batch(&x, &th, &v, &mut fast);
        let mut vc = vec![0.0; 6];
        let mut oc = vec![0.0; 6];
        for j in 0..3 {
            v.col_into(j, &mut vc);
            quad.hvp_xx(&x, &th, &vc, &mut oc);
            for i in 0..6 {
                assert!((fast.at(i, j) - oc[i]).abs() < 1e-10);
            }
        }
        let vt = Mat::randn(4, 3, &mut rng);
        let mut cross = Mat::zeros(6, 3);
        quad.jvp_x_theta_batch(&x, &th, &vt, &mut cross);
        let mut vtc = vec![0.0; 4];
        for j in 0..3 {
            vt.col_into(j, &mut vtc);
            quad.jvp_x_theta(&x, &th, &vtc, &mut oc);
            for i in 0..6 {
                assert!((cross.at(i, j) - oc[i]).abs() < 1e-10);
            }
        }
        let u = Mat::randn(6, 3, &mut rng);
        let mut back = Mat::zeros(4, 3);
        quad.vjp_x_theta_batch(&x, &th, &u, &mut back);
        let mut uc = vec![0.0; 6];
        let mut bc = vec![0.0; 4];
        for j in 0..3 {
            u.col_into(j, &mut uc);
            quad.vjp_x_theta(&x, &th, &uc, &mut bc);
            for i in 0..4 {
                assert!((back.at(i, j) - bc[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cross_product_adjoint_identity() {
        let quad = random_quad(6, 4, 3);
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(6);
        let th = rng.normal_vec(4);
        let v = rng.normal_vec(4);
        let u = rng.normal_vec(6);
        let mut jv = vec![0.0; 6];
        quad.jvp_x_theta(&x, &th, &v, &mut jv);
        let mut vj = vec![0.0; 4];
        quad.vjp_x_theta(&x, &th, &u, &mut vj);
        let lhs = crate::linalg::vecops::dot(&u, &jv);
        let rhs = crate::linalg::vecops::dot(&vj, &v);
        assert!((lhs - rhs).abs() < 1e-10);
    }
}
