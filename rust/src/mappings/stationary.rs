//! Stationary-point condition (paper Eq. 4): F(x, θ) = ∇₁f(x, θ).
//! A = −∂₁F = −∇₁²f is symmetric (CG applies); B = ∂₂∇₁f.
//! The gradient-descent fixed point (Eq. 5) yields the same linear system —
//! the η factor cancels — which the tests verify.

use super::objective::Objective;
use crate::diff::spec::{FixedPointMap, RootMap};
use crate::linalg::mat::Mat;

/// F(x, θ) = ∇₁f(x, θ).
pub struct StationaryMapping<O: Objective> {
    pub obj: O,
}

impl<O: Objective> StationaryMapping<O> {
    pub fn new(obj: O) -> Self {
        StationaryMapping { obj }
    }
}

impl<O: Objective> RootMap for StationaryMapping<O> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.obj.grad_x(x, theta, out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.obj.hvp_xx(x, theta, v, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.obj.hvp_xx(x, theta, u, out); // Hessian symmetric
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.obj.jvp_x_theta(x, theta, v, out);
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.obj.vjp_x_theta(x, theta, u, out);
    }
    // Batched products delegate to the objective's batched oracles (a single
    // GEMM for models that materialize their Hessian).
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.obj.hvp_xx_batch(x, theta, v, out);
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.obj.hvp_xx_batch(x, theta, u, out); // Hessian symmetric
    }
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.obj.jvp_x_theta_batch(x, theta, v, out);
    }
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.obj.vjp_x_theta_batch(x, theta, u, out);
    }
    fn a_symmetric(&self) -> bool {
        true
    }
}

/// Gradient-descent fixed point (Eq. 5): T(x, θ) = x − η∇₁f(x, θ).
pub struct GradientDescentFixedPoint<O: Objective> {
    pub obj: O,
    pub eta: f64,
}

impl<O: Objective> FixedPointMap for GradientDescentFixedPoint<O> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.obj.grad_x(x, theta, out);
        for i in 0..x.len() {
            out[i] = x[i] - self.eta * out[i];
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.obj.hvp_xx(x, theta, v, out);
        for i in 0..v.len() {
            out[i] = v[i] - self.eta * out[i];
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_x(x, theta, u, out);
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.obj.jvp_x_theta(x, theta, v, out);
        for o in out.iter_mut() {
            *o *= -self.eta;
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.obj.vjp_x_theta(x, theta, u, out);
        for o in out.iter_mut() {
            *o *= -self.eta;
        }
    }
    // Batched ∂₁T·V = V − η·(∇²f)·V: one batched HVP for the whole block.
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.obj.hvp_xx_batch(x, theta, v, out);
        for (o, vi) in out.data.iter_mut().zip(v.data.iter()) {
            *o = *vi - self.eta * *o;
        }
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.jvp_x_batch(x, theta, u, out); // symmetric
    }
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.obj.jvp_x_theta_batch(x, theta, v, out);
        for o in out.data.iter_mut() {
            *o *= -self.eta;
        }
    }
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.obj.vjp_x_theta_batch(x, theta, u, out);
        for o in out.data.iter_mut() {
            *o *= -self.eta;
        }
    }
    fn a_symmetric(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::jacobian_via_root;
    use crate::diff::spec::FixedPointResidual;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    fn random_quad(d: usize, n: usize, seed: u64) -> QuadObjective {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(d, n, &mut rng);
        let c = rng.normal_vec(d);
        QuadObjective { q, r, c }
    }

    /// For the quadratic, x*(θ) = −Q⁻¹(Rθ + c) and ∂x* = −Q⁻¹R exactly.
    fn solve_quad(q: &QuadObjective, theta: &[f64]) -> (Vec<f64>, Mat) {
        let ch = Cholesky::factor(&q.q).unwrap();
        let rt = q.r.matvec(theta);
        let rhs: Vec<f64> = rt.iter().zip(&q.c).map(|(a, b)| -(a + b)).collect();
        let x = ch.solve(&rhs);
        let jac_true = {
            let minus_r = q.r.map(|v| -v);
            ch.solve_mat(&minus_r)
        };
        (x, jac_true)
    }

    #[test]
    fn stationary_jacobian_matches_closed_form() {
        let quad = random_quad(6, 3, 1);
        let theta = vec![0.5, -1.0, 2.0];
        let (x_star, jac_true) = solve_quad(&quad, &theta);
        let m = StationaryMapping::new(quad);
        let jac = jacobian_via_root(&m, &x_star, &theta);
        for i in 0..6 {
            for j in 0..3 {
                assert!(
                    (jac.at(i, j) - jac_true.at(i, j)).abs() < 1e-7,
                    "({i},{j}): {} vs {}",
                    jac.at(i, j),
                    jac_true.at(i, j)
                );
            }
        }
    }

    #[test]
    fn gd_fixed_point_gives_same_jacobian_for_any_eta() {
        let theta = vec![1.0, 0.3, -0.7];
        let (x_star, jac_true) = solve_quad(&random_quad(5, 3, 2), &theta);
        for eta in [0.05, 0.2, 0.9] {
            let fp = GradientDescentFixedPoint { obj: random_quad(5, 3, 2), eta };
            let res = FixedPointResidual(fp);
            let jac = jacobian_via_root(&res, &x_star, &theta);
            for i in 0..5 {
                for j in 0..3 {
                    assert!(
                        (jac.at(i, j) - jac_true.at(i, j)).abs() < 1e-6,
                        "eta={eta} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn root_is_actually_stationary() {
        let quad = random_quad(4, 2, 3);
        let theta = vec![0.1, 0.2];
        let (x_star, _) = solve_quad(&quad, &theta);
        let m = StationaryMapping::new(quad);
        let f = m.eval_vec(&x_star, &theta);
        assert!(crate::linalg::vecops::norm2(&f) < 1e-10);
    }
}
