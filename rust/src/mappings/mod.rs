//! Optimality-mapping catalog — paper Table 1.
//!
//! | Mapping                  | Type        | Oracles                          |
//! |--------------------------|-------------|----------------------------------|
//! | Stationary (Eq. 4/5)     | `RootMap`   | ∇₁f (+ HVP, cross-products)      |
//! | KKT (Eq. 6)              | `RootMap`   | ∇₁f, H, G and their products     |
//! | Proximal gradient (7)    | `FixedPointMap` | ∇₁f, prox_{ηg}               |
//! | Projected gradient (9)   | `FixedPointMap` | ∇₁f, proj_C                  |
//! | Mirror descent (13)      | `FixedPointMap` | ∇₁f, proj^φ_C, ∇φ            |
//! | Newton (14)              | `FixedPointMap` | [∂₁G]⁻¹, G                   |
//! | Block proximal grad (15) | `FixedPointMap` | [∇₁f]ⱼ, [prox]ⱼ              |
//! | Conic programming (18)   | `RootMap`   | proj onto R^p × K* × R₊          |
//!
//! Every mapping decouples *what characterizes optimality* from *how the
//! problem is solved* — the paper's modularity claim; Fig. 4(c) pairs a BCD
//! solver with MD/PG fixed points through exactly these types.

pub mod conic;
pub mod kkt;
pub mod mirror;
pub mod newton;
pub mod objective;
pub mod prox_grad;
pub mod stationary;

pub use mirror::{KlMirrorDescentFixedPoint, MirrorGeometry};
pub use objective::Objective;
pub use prox_grad::{BlockProxGradFixedPoint, ProjGradFixedPoint, ProxGradFixedPoint};
pub use stationary::StationaryMapping;
