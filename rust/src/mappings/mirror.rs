//! Mirror-descent fixed point (paper Eq. 13, Appendix A) —
//! x̂ = ∇φ(x), y = x̂ − η∇₁f(x, θ), T(x, θ) = proj^φ_C(y).
//!
//! The KL geometry (φ = ⟨x, log x − 1⟩) over products of simplices is the
//! instance the multiclass-SVM experiment uses: ∇φ(x) = log x and the
//! Bregman projection is a row-wise softmax, "easy to compute and
//! differentiate" per the paper.

use super::objective::Objective;
use crate::diff::spec::FixedPointMap;
use crate::proj::simplex;

/// Mirror map and Bregman projection for a geometry.
pub trait MirrorGeometry {
    fn dim(&self) -> usize;
    /// x̂ = ∇φ(x).
    fn mirror_map(&self, x: &[f64], out: &mut [f64]);
    /// out = ∂∇φ(x) · v (diagonal for separable φ).
    fn mirror_map_jvp(&self, x: &[f64], v: &[f64], out: &mut [f64]);
    /// Bregman projection of the dual point y onto C.
    fn bregman_project(&self, y: &[f64], out: &mut [f64]);
    /// out = ∂proj(y) · v.
    fn bregman_project_jvp(&self, y: &[f64], v: &[f64], out: &mut [f64]);
    /// out = ∂proj(y)ᵀ · v (softmax Jacobian is symmetric; default = jvp).
    fn bregman_project_vjp(&self, y: &[f64], v: &[f64], out: &mut [f64]) {
        self.bregman_project_jvp(y, v, out);
    }
}

/// KL geometry over a product of m simplices of size k (row-major m×k).
pub struct KlSimplexRows {
    pub m: usize,
    pub k: usize,
}

impl MirrorGeometry for KlSimplexRows {
    fn dim(&self) -> usize {
        self.m * self.k
    }
    fn mirror_map(&self, x: &[f64], out: &mut [f64]) {
        for i in 0..x.len() {
            out[i] = x[i].max(1e-300).ln();
        }
    }
    fn mirror_map_jvp(&self, x: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..x.len() {
            out[i] = v[i] / x[i].max(1e-300);
        }
    }
    fn bregman_project(&self, y: &[f64], out: &mut [f64]) {
        simplex::softmax_rows(y, self.k, out);
    }
    fn bregman_project_jvp(&self, y: &[f64], v: &[f64], out: &mut [f64]) {
        let mut p = vec![0.0; y.len()];
        simplex::softmax_rows(y, self.k, &mut p);
        simplex::rows_softmax_jacobian_product(&p, self.k, v, out);
    }
}

/// The mirror-descent fixed point T(x, θ) = proj^φ(∇φ(x) − η∇₁f(x, θ)).
pub struct KlMirrorDescentFixedPoint<O: Objective, G: MirrorGeometry> {
    pub obj: O,
    pub geom: G,
    pub eta: f64,
}

impl<O: Objective, G: MirrorGeometry> KlMirrorDescentFixedPoint<O, G> {
    pub fn new(obj: O, geom: G, eta: f64) -> Self {
        assert_eq!(obj.dim_x(), geom.dim());
        KlMirrorDescentFixedPoint { obj, geom, eta }
    }

    /// y = ∇φ(x) − η∇₁f(x, θ).
    fn dual_point(&self, x: &[f64], theta: &[f64]) -> Vec<f64> {
        let d = x.len();
        let mut xhat = vec![0.0; d];
        self.geom.mirror_map(x, &mut xhat);
        let mut g = vec![0.0; d];
        self.obj.grad_x(x, theta, &mut g);
        (0..d).map(|i| xhat[i] - self.eta * g[i]).collect()
    }
}

impl<O: Objective, G: MirrorGeometry> FixedPointMap for KlMirrorDescentFixedPoint<O, G> {
    fn dim_x(&self) -> usize {
        self.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.obj.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let y = self.dual_point(x, theta);
        self.geom.bregman_project(&y, out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let d = x.len();
        let y = self.dual_point(x, theta);
        // dy = ∂∇φ(x)v − η∇₁²f v
        let mut dphi = vec![0.0; d];
        self.geom.mirror_map_jvp(x, v, &mut dphi);
        let mut hv = vec![0.0; d];
        self.obj.hvp_xx(x, theta, v, &mut hv);
        let dy: Vec<f64> = (0..d).map(|i| dphi[i] - self.eta * hv[i]).collect();
        self.geom.bregman_project_jvp(&y, &dy, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let d = x.len();
        let y = self.dual_point(x, theta);
        let mut w = vec![0.0; d];
        self.geom.bregman_project_vjp(&y, u, &mut w);
        // (∂∇φ)ᵀw − η Hᵀw; ∂∇φ diagonal, H symmetric.
        let mut dphi_w = vec![0.0; d];
        self.geom.mirror_map_jvp(x, &w, &mut dphi_w);
        let mut hw = vec![0.0; d];
        self.obj.hvp_xx(x, theta, &w, &mut hw);
        for i in 0..d {
            out[i] = dphi_w[i] - self.eta * hw[i];
        }
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let d = x.len();
        let y = self.dual_point(x, theta);
        let mut cross = vec![0.0; d];
        self.obj.jvp_x_theta(x, theta, v, &mut cross);
        let dy: Vec<f64> = cross.iter().map(|c| -self.eta * c).collect();
        self.geom.bregman_project_jvp(&y, &dy, out);
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let d = x.len();
        let y = self.dual_point(x, theta);
        let mut w = vec![0.0; d];
        self.geom.bregman_project_vjp(&y, u, &mut w);
        let mut vf = vec![0.0; self.obj.dim_theta()];
        self.obj.vjp_x_theta(x, theta, &w, &mut vf);
        for i in 0..out.len() {
            out[i] = -self.eta * vf[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::FixedPointMap;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    fn simplex_point(rng: &mut Rng, m: usize, k: usize) -> Vec<f64> {
        let mut x = vec![0.0; m * k];
        for r in 0..m {
            let raw = rng.uniform_vec(k);
            let s: f64 = raw.iter().sum();
            for j in 0..k {
                x[r * k + j] = raw[j] / s;
            }
        }
        x
    }

    fn random_quad(d: usize, n: usize, seed: u64) -> QuadObjective {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(d, n, &mut rng);
        let c = rng.normal_vec(d);
        QuadObjective { q, r, c }
    }

    #[test]
    fn output_stays_on_simplices() {
        let (m, k) = (3, 4);
        let t = KlMirrorDescentFixedPoint::new(
            random_quad(m * k, 2, 1),
            KlSimplexRows { m, k },
            0.5,
        );
        let mut rng = Rng::new(2);
        let x = simplex_point(&mut rng, m, k);
        let theta = [0.1, -0.3];
        let out = t.eval_vec(&x, &theta);
        for r in 0..m {
            let s: f64 = out[r * k..(r + 1) * k].iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(out[r * k..(r + 1) * k].iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn jacobians_match_fd() {
        let (m, k) = (2, 3);
        let t = KlMirrorDescentFixedPoint::new(
            random_quad(m * k, 2, 3),
            KlSimplexRows { m, k },
            0.3,
        );
        let mut rng = Rng::new(4);
        let x = simplex_point(&mut rng, m, k);
        let theta = [0.2, 0.5];
        let v = rng.normal_vec(m * k);
        let mut jv = vec![0.0; m * k];
        t.jvp_x(&x, &theta, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|xx| t.eval_vec(xx, &theta), &x, &v, 1e-7);
        for i in 0..m * k {
            assert!((jv[i] - fd[i]).abs() < 1e-5, "{} vs {}", jv[i], fd[i]);
        }
        let vt = rng.normal_vec(2);
        let mut jt = vec![0.0; m * k];
        t.jvp_theta(&x, &theta, &vt, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|tt| t.eval_vec(&x, tt), &theta, &vt, 1e-7);
        for i in 0..m * k {
            assert!((jt[i] - fd[i]).abs() < 1e-5);
        }
        // adjoints
        let u = rng.normal_vec(m * k);
        let mut vx = vec![0.0; m * k];
        t.vjp_x(&x, &theta, &u, &mut vx);
        let lhs = crate::linalg::vecops::dot(&u, &jv);
        let rhs = crate::linalg::vecops::dot(&vx, &v);
        assert!((lhs - rhs).abs() < 1e-8);
        let mut vth = vec![0.0; 2];
        t.vjp_theta(&x, &theta, &u, &mut vth);
        let lhs = crate::linalg::vecops::dot(&u, &jt);
        let rhs = crate::linalg::vecops::dot(&vth, &vt);
        assert!((lhs - rhs).abs() < 1e-8);
    }

    #[test]
    fn fixed_point_of_entropy_regularized_problem() {
        // minimize ⟨x, c⟩ over △ with MD: the fixed point of T is the
        // constrained optimum (a vertex-leaning distribution).
        let (m, k) = (1, 4);
        let mut rng = Rng::new(5);
        let q = Mat::zeros(k, k).plus_diag(1e-6);
        let r = Mat::from_fn(k, 1, |i, _| (i as f64) - 1.5); // linear costs via θ
        let c = vec![0.0; k];
        let obj = QuadObjective { q, r, c };
        let t = KlMirrorDescentFixedPoint::new(obj, KlSimplexRows { m, k }, 1.0);
        let theta = [1.0];
        let mut x = simplex_point(&mut rng, m, k);
        for _ in 0..5000 {
            x = t.eval_vec(&x, &theta);
        }
        // cost coefficients increase with i ⇒ optimum concentrates on i = 0.
        assert!(x[0] > 0.99, "x = {x:?}");
    }
}
