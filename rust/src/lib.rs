//! # `idiff` — Efficient and Modular Implicit Differentiation
//!
//! A Rust + JAX + Pallas reproduction of *Efficient and Modular Implicit
//! Differentiation* (Blondel et al., NeurIPS 2022 — the JAXopt paper).
//!
//! The library lets you differentiate the solution `x*(θ)` of an optimization
//! problem through a user-supplied **optimality mapping** `F(x, θ)` (root
//! form, `F(x*(θ), θ) = 0`) or **fixed-point mapping** `T(x, θ)`
//! (`x*(θ) = T(x*(θ), θ)`), combining the implicit function theorem with
//! automatic differentiation of `F` — exactly the paper's recipe, with the
//! same decoupling: *any* solver can be paired with *any* optimality mapping.
//!
//! ## Layer map
//! - **L3 (this crate)**: the implicit-diff engine ([`diff`]), the catalog of
//!   optimality mappings ([`mappings`], paper Table 1), projections
//!   ([`proj`], Appendix C.1) and proximity operators ([`prox`], C.2),
//!   matrix-free linear solvers ([`linalg`]), a from-scratch autodiff
//!   ([`ad`]), inner solvers ([`solvers`]), the unrolling baseline
//!   ([`unroll`]), bi-level drivers ([`bilevel`]), datasets/models
//!   ([`data`], [`ml`]), molecular dynamics ([`md`]), the PJRT runtime
//!   ([`runtime`]) and the experiment coordinator ([`coordinator`]).
//! - **L2/L1 (build-time Python)**: `python/compile/` lowers JAX + Pallas
//!   compute oracles to HLO text artifacts which [`runtime`] loads and
//!   executes on the request path — Python never runs at serve time.
//!
//! ## Quickstart (paper Figure 1 equivalent)
//! ```
//! use idiff::ml::ridge::{RidgeProblem, RidgeRoot};
//! // Ridge regression: F(x, θ) = ∇₁f(x, θ) = Xᵀ(Xx − y) + θ⊙x.
//! let (xm, y) = idiff::data::regression::diabetes_like(64, 8, 7);
//! let ridge = RidgeProblem::new(xm, y);
//! let theta = vec![10.0; 8];
//! let x_star = ridge.solve_closed_form_vec(&theta);
//! let jac = idiff::diff::jacobian_via_root(&RidgeRoot(&ridge), &x_star, &theta);
//! assert_eq!((jac.rows, jac.cols), (8, 8));
//! ```
#![allow(clippy::needless_range_loop)]

pub mod ad;
pub mod bilevel;
pub mod coordinator;
pub mod data;
pub mod diff;
pub mod linalg;
pub mod mappings;
pub mod md;
pub mod ml;
pub mod proj;
pub mod prox;
pub mod runtime;
pub mod solvers;
pub mod unroll;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::ad::dual::Dual;
    pub use crate::diff::fixed_point::CustomFixedPoint;
    pub use crate::diff::root::{implicit_jvp, implicit_vjp, CustomRoot};
    pub use crate::diff::spec::{FixedPointMap, RootMap};
    pub use crate::linalg::op::LinOp;
    pub use crate::linalg::solve::{LinearSolveConfig, LinearSolverKind};
    pub use crate::linalg::Mat;
    pub use crate::util::rng::Rng;
}
