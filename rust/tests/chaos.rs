//! Chaos sweep: the serving tier under injected faults.
//!
//! Each scenario spawns real shard/router processes with an `IDIFF_FAULTS`
//! plan in the environment of exactly the process under attack, then drives
//! client traffic through the front door and checks ONE invariant:
//!
//! > every request is answered — a result or a typed error
//! > (`overloaded` / `deadline_exceeded` / `no healthy shards`) — within
//! > its deadline budget; nothing ever hangs.
//!
//! Scenarios: dropped requests and replies truncated mid-frame on a shard
//! (router must fail over, never relay a partial line), dropped forwards
//! inside the router (jittered retry), actor panics (supervisor restarts,
//! counted), and injected latency against a tight deadline (typed
//! `deadline_exceeded`, bounded wall time). A final non-faulted scenario
//! measures failover recovery time with and without warm-state replication
//! and journals the rows to `BENCH_faults.json` for the CI `chaos` job.
//!
//! Fault plans ride in child-process environments, so the scenarios are
//! independent and safe to run in parallel test threads.

use idiff::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Client-side ceiling: any reply slower than this counts as a hang.
const HANG: Duration = Duration::from_secs(20);

// ---------------------------------------------------------------- helpers --

struct Proc {
    child: Child,
    addr: String,
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn an `idiff` child with extra environment (the fault plan goes only
/// into the process under attack) and wait for its listen announcement.
fn spawn_idiff(args: &[&str], envs: &[(&str, &str)], tag: &str) -> Proc {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_idiff"));
    cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::null());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("spawn idiff");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if reader.read_line(&mut line).expect("read child stdout") == 0 {
            panic!("{tag} exited before announcing its address");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().expect("address token").to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    Proc { child, addr }
}

/// Reserve two distinct loopback ports (both bound before either drops).
fn reserve_two_ports() -> (u16, u16) {
    let a = TcpListener::bind("127.0.0.1:0").expect("reserve port a");
    let b = TcpListener::bind("127.0.0.1:0").expect("reserve port b");
    (a.local_addr().unwrap().port(), b.local_addr().unwrap().port())
}

fn hypergrad_line(theta: &[f64], v: &[f64], deadline_ms: Option<u64>) -> String {
    let mut members = vec![
        ("op", Json::Str("hypergrad".to_string())),
        ("problem", Json::Str("ridge".to_string())),
        ("theta", Json::arr_f64(theta)),
        ("v", Json::arr_f64(v)),
    ];
    if let Some(ms) = deadline_ms {
        members.push(("deadline_ms", Json::Num(ms as f64)));
    }
    Json::obj(members).to_string_compact()
}

/// One fresh-connection request that tolerates injected failure: `None`
/// means the connection died or timed out (never a silent hang — the read
/// timeout bounds it), `Some` is a parsed reply line.
fn try_request(addr: &str, line: &str, timeout: Duration) -> Option<Json> {
    let mut stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(timeout)).ok()?;
    stream.set_write_timeout(Some(timeout)).ok()?;
    let mut reader = BufReader::new(stream.try_clone().ok()?);
    stream.write_all(line.as_bytes()).ok()?;
    stream.write_all(b"\n").ok()?;
    let mut resp = String::new();
    if reader.read_line(&mut resp).ok()? == 0 || !resp.ends_with('\n') {
        return None;
    }
    idiff::util::json::parse(resp.trim()).ok()
}

/// Non-tolerant request: the route under test must always answer.
fn request(addr: &str, line: &str) -> Json {
    try_request(addr, line, HANG)
        .unwrap_or_else(|| panic!("request through {addr} hung or died: {line}"))
}

/// One numeric stats field straight from a process, retried a few times so
/// an injected fault on the stats connection itself cannot flake the test.
fn stat(addr: &str, key: &str) -> f64 {
    for _ in 0..5 {
        if let Some(r) = try_request(addr, r#"{"op":"stats"}"#, Duration::from_secs(5)) {
            if let Some(x) = r.get(key).and_then(Json::as_f64) {
                return x;
            }
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("could not read stats field '{key}' from {addr}");
}

fn thetas(n: usize) -> Vec<Vec<f64>> {
    (0..n).map(|i| vec![1.0 + 0.01 * i as f64; 8]).collect()
}

/// The full typed-error vocabulary a faulted cluster may answer with.
fn is_typed_error(r: &Json) -> bool {
    matches!(
        r.get("error").and_then(|e| e.as_str()),
        Some("overloaded") | Some("deadline_exceeded") | Some("no healthy shards")
    )
}

fn spawn_two_shards_and_router(
    shard0_env: &[(&str, &str)],
    router_env: &[(&str, &str)],
    peers: bool,
    router_extra: &[&str],
) -> (Proc, Proc, Proc) {
    let (pa, pb) = reserve_two_ports();
    let addr_a = format!("127.0.0.1:{pa}");
    let addr_b = format!("127.0.0.1:{pb}");
    let both = format!("{addr_a},{addr_b}");
    let mut args0 =
        vec!["serve", "--addr", &addr_a, "--workers", "2", "--window-ms", "0", "--shard", "0/2"];
    let mut args1 =
        vec!["serve", "--addr", &addr_b, "--workers", "2", "--window-ms", "0", "--shard", "1/2"];
    if peers {
        for args in [&mut args0, &mut args1] {
            args.extend_from_slice(&["--peers", &both, "--replicate-secs", "1"]);
        }
    }
    let shard0 = spawn_idiff(&args0, shard0_env, "shard 0");
    let shard1 = spawn_idiff(&args1, &[], "shard 1");
    let mut rargs = vec![
        "route", "--addr", "127.0.0.1:0", "--workers", "2", "--health-secs", "1", "--shards",
        &both,
    ];
    rargs.extend_from_slice(router_extra);
    let router = spawn_idiff(&rargs, router_env, "router");
    (shard0, shard1, router)
}

// -------------------------------------------- 1. shard drops + truncation --

#[test]
fn dropped_requests_and_truncated_replies_are_answered_typed_and_bounded() {
    let plan = "shard-request=drop@4,shard-reply=close-mid-frame@5";
    let (_shard0, _shard1, router) =
        spawn_two_shards_and_router(&[("IDIFF_FAULTS", plan)], &[], false, &[]);
    let v = vec![0.5; 8];
    let mut ok = 0usize;
    for t in &thetas(24) {
        let t0 = Instant::now();
        let r = request(&router.addr, &hypergrad_line(t, &v, Some(2500)));
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "reply took {:?} against a 2.5s deadline",
            t0.elapsed()
        );
        if r.get("grad").is_some() {
            ok += 1;
        } else {
            assert!(is_typed_error(&r), "untyped reply under faults: {}", r.to_string_compact());
        }
    }
    // The fault-free shard plus failover keeps well over half the traffic up.
    assert!(ok >= 12, "only {ok}/24 requests served under shard faults");
}

// ------------------------------------------------- 2. router-forward drops --

#[test]
fn router_forward_drops_retry_onto_a_survivor_and_every_request_succeeds() {
    // Threshold 3 keeps single drops from opening a breaker: the retry
    // re-hashes within the same ring and must succeed on its own.
    let (_shard0, _shard1, router) = spawn_two_shards_and_router(
        &[],
        &[("IDIFF_FAULTS", "router-forward=drop@3")],
        false,
        &["--breaker-threshold", "3"],
    );
    let v = vec![0.5; 8];
    for t in &thetas(24) {
        let r = request(&router.addr, &hypergrad_line(t, &v, Some(10_000)));
        assert!(
            r.get("grad").is_some(),
            "a dropped forward must be retried, not surfaced: {}",
            r.to_string_compact()
        );
    }
    let retried = stat(&router.addr, "failovers");
    assert!(retried >= 1.0, "the drop plan never fired (failovers = {retried})");
}

// --------------------------------------------------------- 3. actor panics --

#[test]
fn actor_panics_are_supervised_restarted_and_counted() {
    let shard = spawn_idiff(
        &["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--window-ms", "0"],
        &[("IDIFF_FAULTS", "actor=panic@5")],
        "shard",
    );
    let v = vec![0.5; 8];
    let (mut ok, mut dropped) = (0usize, 0usize);
    for t in &thetas(25) {
        // Fresh connection per request: each one is a supervised message,
        // so every 5th connection dies to the injected panic.
        let t0 = Instant::now();
        match try_request(&shard.addr, &hypergrad_line(t, &v, None), Duration::from_secs(5)) {
            Some(r) if r.get("grad").is_some() => ok += 1,
            Some(r) => panic!("unexpected reply: {}", r.to_string_compact()),
            None => dropped += 1,
        }
        assert!(t0.elapsed() < Duration::from_secs(6), "connection neither served nor died");
    }
    assert!(dropped >= 1, "the panic plan never fired");
    assert!(ok >= 15, "supervisor failed to keep the shard serving: {ok}/25");
    assert!(
        stat(&shard.addr, "actor_restarts") >= 1.0,
        "panics must be recovered by the supervisor, not eaten"
    );
    assert_eq!(stat(&shard.addr, "actor_give_ups"), 0.0, "far below the storm threshold");
}

// ------------------------------------------- 4. injected latency, deadline --

#[test]
fn injected_latency_against_a_tight_deadline_yields_typed_deadline_errors() {
    let (_shard0, _shard1, router) = spawn_two_shards_and_router(
        &[("IDIFF_FAULTS", "shard-request=delay-3000")],
        &[],
        false,
        &[],
    );
    let v = vec![0.5; 8];
    let (mut served, mut expired) = (0usize, 0usize);
    for t in &thetas(24) {
        let t0 = Instant::now();
        let r = request(&router.addr, &hypergrad_line(t, &v, Some(500)));
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "a 500ms deadline took {:?} to resolve",
            t0.elapsed()
        );
        if r.get("grad").is_some() {
            served += 1;
        } else {
            assert_eq!(
                r.get("error").and_then(|e| e.as_str()),
                Some("deadline_exceeded"),
                "slow shard must yield the typed deadline error: {}",
                r.to_string_compact()
            );
            expired += 1;
        }
    }
    // The ring splits the θ's across both shards: the slow shard's slice
    // expires, the healthy shard's slice is served.
    assert!(served >= 1, "healthy shard's slice should still be served");
    assert!(expired >= 1, "delayed shard's slice should expire typed");
    assert!(stat(&router.addr, "deadline_exceeded") >= expired as f64);
}

// ----------------------------- 5. failover recovery journal (no faults) --

/// Warm `n` θ's through the router; returns per-shard factorization counts.
fn warm(router: &Proc, shard0: &Proc, shard1: &Proc, n: usize) -> (f64, f64) {
    let v = vec![0.5; 8];
    for t in &thetas(n) {
        let r = request(&router.addr, &hypergrad_line(t, &v, None));
        assert!(r.get("error").is_none(), "warmup: {}", r.to_string_compact());
    }
    (stat(&shard0.addr, "factorizations"), stat(&shard1.addr, "factorizations"))
}

/// Kill shard 0, then time (a) the first successful reply and (b) a full
/// clean pass over every θ. Returns (first_ms, pass_ms, new_factorizations).
fn measure_failover(router: &Proc, shard0: Proc, shard1: &Proc, n: usize) -> (f64, f64, f64) {
    let v = vec![0.5; 8];
    let before = stat(&shard1.addr, "factorizations");
    drop(shard0); // SIGKILL
    let t0 = Instant::now();
    let mut first_ms = None;
    for t in &thetas(n) {
        let r = request(&router.addr, &hypergrad_line(t, &v, Some(15_000)));
        assert!(r.get("error").is_none(), "failover: {}", r.to_string_compact());
        first_ms.get_or_insert_with(|| t0.elapsed().as_secs_f64() * 1e3);
    }
    let pass_ms = t0.elapsed().as_secs_f64() * 1e3;
    (first_ms.unwrap(), pass_ms, stat(&shard1.addr, "factorizations") - before)
}

#[test]
fn failover_recovery_is_journaled_replicated_vs_cold() {
    let n = 16;

    // Replicated: wait for the warm slice to land on the successor first.
    let (shard0, shard1, router) = spawn_two_shards_and_router(&[], &[], true, &[]);
    let (f0, f1) = warm(&router, &shard0, &shard1, n);
    assert_eq!(f0 + f1, n as f64);
    let deadline = Instant::now() + Duration::from_secs(30);
    while stat(&shard1.addr, "replicated_in") < f0 || stat(&shard0.addr, "replicated_in") < f1 {
        assert!(Instant::now() < deadline, "replication never completed");
        std::thread::sleep(Duration::from_millis(200));
    }
    let (warm_first, warm_pass, warm_new) = measure_failover(&router, shard0, &shard1, n);
    assert_eq!(warm_new, 0.0, "replicated failover must cost zero new factorizations");
    drop(router);
    drop(shard1);

    // Cold: identical cluster, no replication — the survivor re-factors.
    let (shard0, shard1, router) = spawn_two_shards_and_router(&[], &[], false, &[]);
    let (f0, _f1) = warm(&router, &shard0, &shard1, n);
    let (cold_first, cold_pass, cold_new) = measure_failover(&router, shard0, &shard1, n);
    assert_eq!(cold_new, f0, "cold failover re-factors exactly the migrated slice");

    let row = |scenario: &str, first: f64, pass: f64, refactored: f64| {
        Json::obj(vec![
            ("scenario", Json::Str(scenario.to_string())),
            ("thetas", Json::Num(n as f64)),
            ("first_reply_ms", Json::Num(first)),
            ("full_pass_ms", Json::Num(pass)),
            ("new_factorizations", Json::Num(refactored)),
        ])
    };
    let journal = Json::obj(vec![
        ("bench", Json::Str("faults".to_string())),
        (
            "rows",
            Json::Arr(vec![
                row("failover_replicated", warm_first, warm_pass, warm_new),
                row("failover_cold", cold_first, cold_pass, cold_new),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_faults.json", journal.to_string_pretty()) {
        Ok(()) => println!("[chaos] wrote BENCH_faults.json"),
        Err(e) => eprintln!("[chaos] FAILED to write BENCH_faults.json: {e}"),
    }
}
