//! Kill → restart → warm-start: a server that saved its manifest answers
//! repeat-θ traffic after reboot with ZERO new factorizations, zero inner
//! solves, and bitwise-identical hypergradients; the ρ-cache warm-starts
//! the same way so `"mode":"auto"` never re-runs power iteration on θ's a
//! previous process already measured.

use idiff::coordinator::serve::wire::{self, RequestFrame};
use idiff::coordinator::serve::{ServeConfig, Server};
use idiff::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn quiet() -> Server {
    Server::new(ServeConfig { batch_window: Duration::from_millis(0), ..ServeConfig::default() })
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("idiff_warm_{tag}_{}.json", std::process::id()))
}

fn hypergrad_line(problem: &str, theta: &[f64], v: &[f64]) -> String {
    Json::obj(vec![
        ("op", Json::Str("hypergrad".to_string())),
        ("problem", Json::Str(problem.to_string())),
        ("theta", Json::arr_f64(theta)),
        ("v", Json::arr_f64(v)),
    ])
    .to_string_compact()
}

fn grad_of(reply: &Json) -> Vec<f64> {
    reply
        .get("grad")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("no grad in {}", reply.to_string_compact()))
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect()
}

#[test]
fn warm_restart_serves_repeat_theta_with_zero_factorizations() {
    // Cholesky-cached (ridge, quad) and LU-cached (projgd) problems, two
    // θ's each — the manifest must carry every factorization kind.
    let thetas: Vec<(&str, Vec<f64>, usize)> = vec![
        ("ridge", vec![1.0; 8], 8),
        ("ridge", vec![0.4; 8], 8),
        ("quad", vec![0.5, 0.6, 0.7, 0.8], 6),
        ("projgd", vec![0.2, 0.4, 0.6, 0.8, 1.0], 5),
    ];

    // ---- life 1: serve, warm, persist, die -------------------------------
    let a = quiet();
    let mut cached_grads = Vec::new();
    for (problem, theta, dim_x) in &thetas {
        let v = vec![0.5; *dim_x];
        let first = a.handle(&hypergrad_line(problem, theta, &v));
        assert!(first.get("error").is_none(), "{}", first.to_string_compact());
        // Second pass takes the factored path — THIS is the answer a warm
        // restart must reproduce bitwise.
        let second = a.handle(&hypergrad_line(problem, theta, &v));
        assert_eq!(second.get("cached"), Some(&Json::Bool(true)));
        cached_grads.push(grad_of(&second));
    }
    let lived_factorizations = a.stats.factorizations.load(Ordering::Relaxed);
    assert_eq!(lived_factorizations, thetas.len() as u64);
    let path = tmp_path("restart");
    a.save_manifest(&path).unwrap();
    drop(a); // the "kill"

    // ---- life 2: boot cold, load manifest, replay ------------------------
    let b = quiet();
    let warm = b.load_manifest(&path).unwrap();
    assert!(warm.cold_start.is_none(), "unexpected cold start: {:?}", warm.cold_start);
    assert_eq!(warm.factorizations as u64, lived_factorizations);
    assert_eq!(warm.skipped, 0);
    for ((problem, theta, dim_x), want) in thetas.iter().zip(&cached_grads) {
        let v = vec![0.5; *dim_x];
        let reply = b.handle(&hypergrad_line(problem, theta, &v));
        assert_eq!(
            reply.get("cached"),
            Some(&Json::Bool(true)),
            "{problem}: warm restart must serve from the restored cache"
        );
        let got = grad_of(&reply);
        assert_eq!(got.len(), want.len());
        for (i, (x, y)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{problem} grad[{i}]: pre-restart {x} vs post-restart {y}"
            );
        }
    }
    // The whole point: the reboot did no derivative work from scratch.
    assert_eq!(b.stats.factorizations.load(Ordering::Relaxed), 0);
    assert_eq!(b.stats.block_solves.load(Ordering::Relaxed), 0);
    assert_eq!(b.stats.inner_solves.load(Ordering::Relaxed), 0);

    // The binary wire sees the same warm state: a frame-decoded repeat-θ
    // request is served cached, bitwise equal to the JSON answer.
    let (problem, theta, dim_x) = &thetas[0];
    let v = vec![0.5; *dim_x];
    let mut frame = Vec::new();
    wire::encode_request(
        &RequestFrame {
            opcode: wire::OP_VJP,
            problem,
            theta,
            v: &v,
            ..RequestFrame::control(wire::OP_VJP)
        },
        &mut frame,
    );
    match b.handle_frame(&frame[wire::REQUEST_HEADER_LEN..]) {
        idiff::coordinator::serve::Reply::Derivative { out, cached, .. } => {
            assert!(cached);
            for (x, y) in cached_grads[0].iter().zip(&out) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        _ => panic!("expected a derivative reply"),
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn rho_cache_persists_so_auto_mode_skips_power_iteration_after_reboot() {
    let a = quiet();
    let theta = vec![0.9; 8];
    let mk = |v0: f64| {
        Json::obj(vec![
            ("op", Json::Str("hypergrad".to_string())),
            ("problem", Json::Str("ridge".to_string())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&vec![v0; 8])),
            ("mode", Json::Str("auto".to_string())),
        ])
        .to_string_compact()
    };
    assert!(a.handle(&mk(1.0)).get("error").is_none());
    assert!(a.handle(&mk(2.0)).get("error").is_none());
    // One estimate for both requests (ρ-cache absorbed the repeat) …
    assert_eq!(a.stats.rho_estimates.load(Ordering::Relaxed), 1);
    // … and auto stayed solve-free on this well-contracting problem.
    assert_eq!(a.stats.factorizations.load(Ordering::Relaxed), 0);
    let path = tmp_path("rho");
    a.save_manifest(&path).unwrap();
    drop(a);

    let b = quiet();
    let warm = b.load_manifest(&path).unwrap();
    assert!(warm.cold_start.is_none());
    assert_eq!(warm.rho_entries, 1);
    assert!(b.handle(&mk(3.0)).get("error").is_none());
    assert_eq!(
        b.stats.rho_estimates.load(Ordering::Relaxed),
        0,
        "auto after reboot must reuse the persisted contraction estimate"
    );
    let _ = std::fs::remove_file(&path);
}

/// Shared shape of every corrupt-manifest case: `load_manifest` must come
/// back `Ok` with a cold-start *reason* (a counted, clean cold start — not
/// an `Err`, not a panic, not a partial restore), and the server must then
/// serve from scratch as if no manifest existed.
fn assert_clean_cold_start(path: &PathBuf, what: &str) {
    let s = quiet();
    let warm = s.load_manifest(path).unwrap_or_else(|e| panic!("{what}: load must be Ok: {e}"));
    assert!(warm.cold_start.is_some(), "{what}: corruption must be reported as a cold start");
    assert_eq!(warm.factorizations + warm.rho_entries, 0, "{what}: nothing may be restored");
    let r = s.handle(&hypergrad_line("ridge", &[1.0; 8], &[1.0; 8]));
    assert!(r.get("error").is_none(), "{what}: {}", r.to_string_compact());
    assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 1, "{what}: cold server factors");
    let _ = std::fs::remove_file(path);
}

#[test]
fn manifest_truncated_mid_write_cold_starts_cleanly() {
    // A crash mid-`save_manifest` leaves a valid prefix of real JSON: warm
    // a server, persist, then cut the file in half.
    let a = quiet();
    let r = a.handle(&hypergrad_line("ridge", &[1.3; 8], &[0.5; 8]));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    let path = tmp_path("truncated");
    a.save_manifest(&path).unwrap();
    drop(a);
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.len() > 64, "manifest unexpectedly small");
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert_clean_cold_start(&path, "truncated manifest");
}

#[test]
fn manifest_of_garbage_bytes_cold_starts_cleanly() {
    // Not even UTF-8, let alone JSON.
    let path = tmp_path("garbage");
    std::fs::write(&path, [0xff, 0xfe, 0x00, 0x9c, 0xb1, 0x42, 0xff, 0x07]).unwrap();
    assert_clean_cold_start(&path, "garbage-bytes manifest");
}

#[test]
fn manifest_of_wrong_shaped_json_cold_starts_cleanly() {
    // Parses fine, is simply not a manifest.
    let path = tmp_path("wrong_shape");
    std::fs::write(&path, "[1,2,3]").unwrap();
    assert_clean_cold_start(&path, "wrong-shape manifest");
}

#[test]
fn manifest_version_skew_cold_starts_without_crashing_the_server() {
    // A manifest written by some FUTURE version must not wedge this build:
    // it reports a cold start and the server serves normally.
    let path = tmp_path("future");
    std::fs::write(
        &path,
        r#"{"format":"idiff-serve-manifest","version":99,"entries":[{"problem":"ridge","payload":"from-the-future"}]}"#,
    )
    .unwrap();
    let s = quiet();
    let warm = s.load_manifest(&path).unwrap();
    assert!(warm.cold_start.is_some());
    assert_eq!(warm.factorizations + warm.rho_entries, 0);
    // Still a fully functional cold server.
    let r = s.handle(&hypergrad_line("ridge", &[1.0; 8], &[1.0; 8]));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 1);
    let _ = std::fs::remove_file(&path);
}
