//! Binary ↔ JSON protocol equivalence.
//!
//! Two identically-constructed servers in one process replay the same
//! request script — one over the JSON line protocol, one over binary
//! frames — and every reply must agree BITWISE: f64 payloads, cached flags,
//! batch sizes, mode strings, error strings, and the engine counters. This
//! is what "same engine, two wires" means; the exact-f64 JSON formatter
//! (`util::json::fmt_f64`) is what makes bitwise comparison possible at
//! all. A second suite drives malformed and oversized binary frames and
//! asserts the documented error policy: payload errors keep the connection
//! usable, framing errors close it, and a JSON connection on the same port
//! never notices.

use idiff::coordinator::serve::wire::{self, ReplyFrame, RequestFrame};
use idiff::coordinator::serve::{ServeConfig, Server};
use idiff::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

fn start(cfg: ServeConfig) -> (SocketAddr, Arc<Server>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = Arc::new(Server::new(cfg));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    (addr, server)
}

fn quiet_cfg() -> ServeConfig {
    ServeConfig { batch_window: Duration::from_millis(0), ..ServeConfig::default() }
}

struct JsonClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl JsonClient {
    fn connect(addr: SocketAddr) -> JsonClient {
        let stream = TcpStream::connect(addr).expect("connect json");
        let reader = BufReader::new(stream.try_clone().unwrap());
        JsonClient { stream, reader }
    }

    fn request(&mut self, line: &str) -> Json {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        idiff::util::json::parse(reply.trim()).expect("reply parses")
    }
}

struct BinClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl BinClient {
    fn connect(addr: SocketAddr) -> BinClient {
        BinClient { stream: TcpStream::connect(addr).expect("connect bin"), buf: Vec::new() }
    }

    fn request(&mut self, frame: &RequestFrame) -> ReplyFrame {
        self.buf.clear();
        wire::encode_request(frame, &mut self.buf);
        self.stream.write_all(&self.buf).unwrap();
        wire::read_reply(&mut self.stream).expect("read reply frame")
    }

    /// Send raw bytes and try to read one reply frame.
    fn raw(&mut self, bytes: &[u8]) -> std::io::Result<ReplyFrame> {
        self.stream.write_all(bytes)?;
        wire::read_reply(&mut self.stream)
    }
}

/// One scripted request, renderable on either wire.
#[derive(Clone)]
struct Step {
    op: &'static str, // "ping" | "problems" | "stats" | "solve" | "hypergrad" | "jvp" | "jacobian"
    problem: String,
    theta: Vec<f64>,
    v: Vec<f64>,
    mode: Option<&'static str>,
    precision: Option<&'static str>,
    iters: u32,
}

impl Step {
    fn control(op: &'static str) -> Step {
        Step {
            op,
            problem: String::new(),
            theta: Vec::new(),
            v: Vec::new(),
            mode: None,
            precision: None,
            iters: 0,
        }
    }

    fn to_json_line(&self) -> String {
        let mut fields = vec![("op", Json::Str(self.op.to_string()))];
        if !self.problem.is_empty() {
            fields.push(("problem", Json::Str(self.problem.clone())));
        }
        if matches!(self.op, "solve" | "hypergrad" | "jvp" | "jacobian") {
            fields.push(("theta", Json::arr_f64(&self.theta)));
        }
        if matches!(self.op, "hypergrad" | "jvp") {
            fields.push(("v", Json::arr_f64(&self.v)));
        }
        if let Some(m) = self.mode {
            fields.push(("mode", Json::Str(m.to_string())));
        }
        if let Some(p) = self.precision {
            fields.push(("precision", Json::Str(p.to_string())));
        }
        if self.iters > 0 {
            fields.push(("iters", Json::Num(self.iters as f64)));
        }
        Json::obj(fields).to_string_compact()
    }

    fn to_frame(&self) -> RequestFrame<'_> {
        let opcode = match self.op {
            "ping" => wire::OP_PING,
            "problems" => wire::OP_PROBLEMS,
            "stats" => wire::OP_STATS,
            "solve" => wire::OP_SOLVE,
            "hypergrad" => wire::OP_VJP,
            "jvp" => wire::OP_JVP,
            "jacobian" => wire::OP_JACOBIAN,
            other => panic!("no opcode for {other}"),
        };
        let mode = match self.mode {
            None => wire::MODE_NONE,
            Some("implicit") => wire::MODE_IMPLICIT,
            Some("unroll") => wire::MODE_UNROLL,
            Some("one-step") => wire::MODE_ONE_STEP,
            Some("auto") => wire::MODE_AUTO,
            Some(other) => panic!("no mode byte for {other}"),
        };
        let precision = match self.precision {
            None | Some("f64") => wire::PREC_F64,
            Some("mixed") => wire::PREC_MIXED,
            Some(other) => panic!("no precision byte for {other}"),
        };
        RequestFrame {
            opcode,
            mode,
            precision,
            iters: self.iters,
            deadline_ms: 0,
            problem: &self.problem,
            theta: &self.theta,
            v: &self.v,
        }
    }
}

fn json_f64s(j: &Json, key: &str) -> Vec<f64> {
    j.get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("no '{key}' in {}", j.to_string_compact()))
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect()
}

fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} (json) vs {y} (binary)");
    }
}

/// Compare a JSON reply and a binary reply frame for one step.
fn assert_equivalent(step: &Step, jr: &Json, bf: &ReplyFrame) {
    let ctx = step.to_json_line();
    if let Some(msg) = jr.get("error").and_then(Json::as_str) {
        assert_eq!(bf.status, wire::STATUS_ERR, "{ctx}: json errored, binary did not");
        assert_eq!(bf.error.as_deref(), Some(msg), "{ctx}: error strings differ");
        return;
    }
    assert_eq!(bf.status, wire::STATUS_OK, "{ctx}: binary errored: {:?}", bf.error);
    match step.op {
        "ping" => {
            assert_eq!(jr.get("ok"), Some(&Json::Bool(true)), "{ctx}");
            assert_eq!((bf.rows, bf.cols), (0, 0), "{ctx}");
        }
        "problems" => {
            let bj = idiff::util::json::parse(&bf.text).expect("problems text parses");
            assert_eq!(jr, &bj, "{ctx}: catalog documents differ");
        }
        "stats" => {
            // Counter VALUES legitimately differ across transports (the
            // binary path also draws reply buffers from the pool), but the
            // surface — the key set — must match.
            let bj = idiff::util::json::parse(&bf.text).expect("stats text parses");
            let keys = |j: &Json| match j {
                Json::Obj(m) => m.keys().cloned().collect::<Vec<String>>(),
                _ => panic!("stats is not an object"),
            };
            assert_eq!(keys(jr), keys(&bj), "{ctx}: stats key sets differ");
        }
        "solve" => {
            assert_bitwise(&json_f64s(jr, "x"), &bf.data, &format!("{ctx}: x"));
            assert_eq!((bf.rows, bf.cols), (bf.data.len(), 1), "{ctx}: shape");
            assert_eq!(jr.get("cached"), Some(&Json::Bool(bf.cached)), "{ctx}: cached");
        }
        "hypergrad" | "jvp" => {
            let key = if step.op == "hypergrad" { "grad" } else { "jv" };
            assert_bitwise(&json_f64s(jr, key), &bf.data, &format!("{ctx}: {key}"));
            assert_eq!(jr.f64_or("batched", -1.0) as usize, bf.batched, "{ctx}: batched");
            assert_eq!(jr.get("cached"), Some(&Json::Bool(bf.cached)), "{ctx}: cached");
            assert_eq!(
                jr.str_or("mode", "<missing>"),
                wire::mode_str_from_byte(bf.mode_byte),
                "{ctx}: mode"
            );
        }
        "jacobian" => {
            let rows = jr.get("jacobian").and_then(Json::as_arr).expect("jacobian rows");
            assert_eq!(rows.len(), bf.rows, "{ctx}: rows");
            let mut flat = Vec::new();
            for row in rows {
                flat.extend(row.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()));
            }
            assert_eq!(bf.rows * bf.cols, flat.len(), "{ctx}: shape");
            assert_bitwise(&flat, &bf.data, &format!("{ctx}: jacobian"));
            assert_eq!(jr.get("cached"), Some(&Json::Bool(bf.cached)), "{ctx}: cached");
        }
        other => panic!("unhandled op {other}"),
    }
}

/// Build the full sweep: every op, every mode × precision on the three
/// densely-factorizable problems (Cholesky on ridge/quad, LU on projgd),
/// default derivatives on the whole catalog, plus engine-level error cases.
fn script(catalog: &[(String, usize, usize)]) -> Vec<Step> {
    let mut steps = vec![Step::control("ping"), Step::control("problems")];
    let theta_for = |dim: usize| (0..dim).map(|i| 0.6 + 0.1 * i as f64).collect::<Vec<f64>>();
    let v_for = |dim: usize| (0..dim).map(|i| 0.3 - 0.05 * i as f64).collect::<Vec<f64>>();
    for (name, dim_x, dim_theta) in catalog {
        let theta = theta_for(*dim_theta);
        steps.push(Step {
            op: "solve",
            problem: name.clone(),
            theta: theta.clone(),
            ..Step::control("solve")
        });
        steps.push(Step {
            op: "jvp",
            problem: name.clone(),
            theta: theta.clone(),
            v: v_for(*dim_theta),
            ..Step::control("jvp")
        });
        let sweep = matches!(name.as_str(), "ridge" | "quad" | "projgd");
        if !sweep {
            continue;
        }
        for mode in [None, Some("one-step"), Some("unroll"), Some("auto")] {
            for precision in [None, Some("mixed")] {
                let iters = if mode == Some("unroll") { 4 } else { 0 };
                steps.push(Step {
                    op: "hypergrad",
                    problem: name.clone(),
                    theta: theta.clone(),
                    v: v_for(*dim_x),
                    mode,
                    precision,
                    iters,
                });
                steps.push(Step {
                    op: "jvp",
                    problem: name.clone(),
                    theta: theta.clone(),
                    v: v_for(*dim_theta),
                    mode,
                    precision,
                    iters,
                });
            }
        }
        steps.push(Step {
            op: "jacobian",
            problem: name.clone(),
            theta: theta.clone(),
            ..Step::control("jacobian")
        });
        // Repeat-θ after the sweep: served from the warmed cache.
        steps.push(Step {
            op: "hypergrad",
            problem: name.clone(),
            theta: theta.clone(),
            v: v_for(*dim_x),
            ..Step::control("hypergrad")
        });
    }
    // Engine-level errors must carry identical strings on both wires.
    steps.push(Step {
        op: "solve",
        problem: "no_such_problem".to_string(),
        theta: vec![1.0],
        ..Step::control("solve")
    });
    steps.push(Step {
        op: "hypergrad",
        problem: "ridge".to_string(),
        theta: theta_for(8),
        v: vec![1.0, 2.0], // wrong length
        ..Step::control("hypergrad")
    });
    steps.push(Step {
        op: "solve",
        problem: "svm".to_string(),
        theta: vec![-1.0], // validate_theta rejects
        ..Step::control("solve")
    });
    steps.push(Step::control("stats"));
    steps
}

#[test]
fn every_op_mode_precision_is_bitwise_identical_on_both_wires() {
    // Two identically-constructed engines in one process (so any process-
    // global state — GEMM autotune config — is shared), one per protocol.
    let (json_addr, json_server) = start(quiet_cfg());
    let (bin_addr, bin_server) = start(quiet_cfg());
    let mut jc = JsonClient::connect(json_addr);
    let mut bc = BinClient::connect(bin_addr);

    // Discover the catalog once, through the wire itself.
    let cat = jc.request(r#"{"op":"problems"}"#);
    let catalog: Vec<(String, usize, usize)> = cat
        .get("problems")
        .and_then(Json::as_arr)
        .expect("problems")
        .iter()
        .map(|p| {
            (
                p.str_or("name", "").to_string(),
                p.f64_or("dim_x", 0.0) as usize,
                p.f64_or("dim_theta", 0.0) as usize,
            )
        })
        .collect();
    assert_eq!(catalog.len(), 7);

    let mut derivative_steps = 0;
    for step in script(&catalog) {
        let jr = jc.request(&step.to_json_line());
        let bf = bc.request(&step.to_frame());
        assert_equivalent(&step, &jr, &bf);
        if matches!(step.op, "hypergrad" | "jvp") {
            derivative_steps += 1;
        }
    }
    assert!(derivative_steps > 40, "sweep actually swept ({derivative_steps} steps)");

    // The two engines walked identical state machines: every engine-level
    // counter agrees (pool counters are transport-dependent by design —
    // the catalog discovery request above is also why `requests` differs).
    use std::sync::atomic::Ordering;
    let pairs = [
        ("block_solves", &json_server.stats.block_solves, &bin_server.stats.block_solves),
        ("inner_solves", &json_server.stats.inner_solves, &bin_server.stats.inner_solves),
        ("factorizations", &json_server.stats.factorizations, &bin_server.stats.factorizations),
        ("densified", &json_server.stats.densified, &bin_server.stats.densified),
        ("rho_estimates", &json_server.stats.rho_estimates, &bin_server.stats.rho_estimates),
        ("cache_hits", &json_server.stats.cache_hits, &bin_server.stats.cache_hits),
    ];
    for (name, a, b) in pairs {
        assert_eq!(a.load(Ordering::Relaxed), b.load(Ordering::Relaxed), "counter {name}");
    }
}

#[test]
fn both_protocols_share_one_port_and_one_cache() {
    let (addr, server) = start(quiet_cfg());
    let mut jc = JsonClient::connect(addr);
    let mut bc = BinClient::connect(addr);
    let theta: Vec<f64> = vec![1.25; 8];
    let v: Vec<f64> = vec![0.5; 8];

    // JSON warms the θ-cache…
    let step = Step {
        op: "hypergrad",
        problem: "ridge".to_string(),
        theta: theta.clone(),
        v: v.clone(),
        ..Step::control("hypergrad")
    };
    let jr = jc.request(&step.to_json_line());
    assert_eq!(jr.get("cached"), Some(&Json::Bool(false)));
    // …and the binary connection reaps the factored fast path, bitwise.
    let bf = bc.request(&step.to_frame());
    assert!(bf.cached, "binary request must hit the cache the JSON request warmed");
    assert_bitwise(&json_f64s(&jr, "grad"), &bf.data, "cross-protocol grad");
    use std::sync::atomic::Ordering;
    assert_eq!(server.stats.block_solves.load(Ordering::Relaxed), 1);
    assert_eq!(server.stats.factorizations.load(Ordering::Relaxed), 1);
}

#[test]
fn malformed_binary_frames_follow_the_error_policy() {
    let (addr, _server) = start(quiet_cfg());

    // 1. Unknown opcode: a payload error — error frame, connection usable.
    let mut bc = BinClient::connect(addr);
    let mut bad = Vec::new();
    wire::encode_request(
        &RequestFrame { opcode: 42, ..RequestFrame::control(wire::OP_PING) },
        &mut bad,
    );
    let f = bc.raw(&bad).unwrap();
    assert_eq!(f.status, wire::STATUS_ERR);
    assert!(f.error.as_deref().unwrap_or("").contains("unknown opcode"), "{:?}", f.error);
    let pong = bc.request(&RequestFrame::control(wire::OP_PING));
    assert_eq!(pong.status, wire::STATUS_OK, "connection must survive a payload error");

    // 2. Truncated f64 block: payload error, connection usable.
    let mut frame = Vec::new();
    wire::encode_request(
        &RequestFrame {
            opcode: wire::OP_SOLVE,
            problem: "ridge",
            theta: &[1.0, 2.0],
            ..RequestFrame::control(wire::OP_SOLVE)
        },
        &mut frame,
    );
    // Lie about n_theta (the u32 right after the 8-byte fixed part + name).
    let at = wire::REQUEST_HEADER_LEN + 8 + 2 + "ridge".len();
    frame[at..at + 4].copy_from_slice(&100u32.to_le_bytes());
    let f = bc.raw(&frame).unwrap();
    assert_eq!(f.status, wire::STATUS_ERR);
    assert!(f.error.as_deref().unwrap_or("").contains("truncated"), "{:?}", f.error);
    let pong = bc.request(&RequestFrame::control(wire::OP_PING));
    assert_eq!(pong.status, wire::STATUS_OK);

    // 3. Oversized payload length: a FRAMING error — error frame, then close.
    let (small_addr, _small) =
        start(ServeConfig { max_line_bytes: 64, ..quiet_cfg() });
    let mut bc2 = BinClient::connect(small_addr);
    let mut huge = vec![wire::MAGIC, wire::VERSION];
    huge.extend_from_slice(&0u32.to_le_bytes()); // deadline field: none
    huge.extend_from_slice(&(1_000_000u32).to_le_bytes());
    let f = bc2.raw(&huge).unwrap();
    assert_eq!(f.status, wire::STATUS_ERR);
    assert!(f.error.as_deref().unwrap_or("").contains("too large"), "{:?}", f.error);
    let mut probe = [0u8; 1];
    assert_eq!(
        bc2.stream.read(&mut probe).unwrap_or(0),
        0,
        "server must close after a framing violation"
    );

    // 4. Wrong protocol version: framing error, then close.
    let mut bc3 = BinClient::connect(addr);
    let mut verr = vec![wire::MAGIC, 99];
    verr.extend_from_slice(&0u32.to_le_bytes()); // deadline field
    verr.extend_from_slice(&0u32.to_le_bytes()); // payload length
    let f = bc3.raw(&verr).unwrap();
    assert_eq!(f.status, wire::STATUS_ERR);
    assert!(f.error.as_deref().unwrap_or("").contains("version"), "{:?}", f.error);
    let mut probe = [0u8; 1];
    assert_eq!(bc3.stream.read(&mut probe).unwrap_or(0), 0);

    // 5. A JSON connection to the same server is oblivious to all of this.
    let mut jc = JsonClient::connect(addr);
    let r = jc.request(r#"{"op":"ping"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
}
