//! Mode-bound sweep over the served catalog: measure the estimated
//! contraction factor ρ = ‖∂₁T(x*, θ)‖₂ of every problem's fixed-point
//! view and assert the solve-free derivative modes sit within their
//! contraction bounds of the implicit-diff answer —
//!
//!   one-step:   ‖(J_os − J_imp)v‖ ≤ C·ρ·‖J_imp v‖
//!   unroll(k):  ‖(J_k − J_imp)v‖ ≤ C·ρᵏ·‖J_imp v‖, non-increasing in k
//!
//! C absorbs two slacks: the power-iteration estimate approaches σ_max
//! from below, and the implicit reference itself carries the iterative
//! solver's tolerance. Entries whose fixed-point view is only certifiably
//! *nonexpansive* (the SVM dual quadratic is rank-deficient, so ρ ≈ 1 up
//! to estimation noise) get the weaker ρ → 1 form of the same bounds.
//! The solve-free products are also checked against each other through the
//! block adjoint identity ⟨U, ∂₂T V⟩ = ⟨∂₂Tᵀ U, V⟩, which holds exactly.

use idiff::coordinator::serve::registry::Registry;
use idiff::linalg::Mat;
use idiff::util::rng::Rng;

/// Bound slack (estimator-from-below + solver tolerance).
const C: f64 = 1.35;
/// Below this ρ̂ the view is a certified contraction with usable geometric
/// bounds; above it (estimation noise away from 1) only nonexpansiveness
/// is certified.
const RHO_STRICT: f64 = 0.98;

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|a| a * a).sum::<f64>().sqrt()
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn err(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    norm(&a.data.iter().zip(&b.data).map(|(x, y)| x - y).collect::<Vec<f64>>())
}

#[test]
fn one_step_and_unroll_errors_obey_contraction_bounds_catalog_wide() {
    let reg = Registry::standard();
    let mut rng = Rng::new(71);
    for p in reg.problems() {
        let n = p.dim_theta();
        let d = p.dim_x();
        let theta: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.5, 1.1)).collect();
        p.validate_theta(&theta).expect("standard θ must validate");
        let x_star = p.solve(&theta);

        let rho = p.contraction(&x_star, &theta);
        assert!(
            rho.is_finite() && (0.0..=1.0 + 1e-9).contains(&rho),
            "{}: rho = {rho} out of the nonexpansive range",
            p.name
        );

        // Block adjoint identity for the solve-free mode — exact.
        let v = Mat::from_col(&rng.normal_vec(n));
        let u = Mat::from_col(&rng.normal_vec(d));
        let jv_os = p.one_step_jvp_multi(&x_star, &theta, &v);
        let ju_os = p.one_step_vjp_multi(&x_star, &theta, &u);
        let lhs = dot(&u.data, &jv_os.data);
        let rhs = dot(&ju_os.data, &v.data);
        assert!(
            (lhs - rhs).abs() <= 1e-10 * lhs.abs().max(rhs.abs()).max(1.0),
            "{}: one-step adjoint identity {lhs} vs {rhs}",
            p.name
        );

        // Implicit reference and the contraction bounds.
        let (jv_imp, rep) = p.jvp_multi(&x_star, &theta, &v);
        assert!(rep.converged, "{}: implicit reference {rep:?}", p.name);
        let nj = norm(&jv_imp.data);
        let floor = 1e-8 * (1.0 + nj);

        let e1 = err(&jv_os, &jv_imp);
        // Effective ρ for the bound: a certified contraction uses its
        // estimate; a merely-nonexpansive view (svm) uses ρ = 1.
        let rho_bound = if rho < RHO_STRICT { rho } else { 1.0 };
        assert!(
            e1 <= C * rho_bound * nj + floor,
            "{}: one-step err {e1} vs C·ρ·‖J_imp v‖ = {} (rho {rho})",
            p.name,
            C * rho_bound * nj
        );

        let mut prev = f64::INFINITY;
        let mut e_first = f64::NAN;
        let mut e_last = f64::NAN;
        for k in [1usize, 2, 4, 8, 16] {
            let jk = p.unroll_jvp_multi(&x_star, &theta, &v, k);
            let ek = err(&jk, &jv_imp);
            assert!(
                ek <= C * rho_bound.powi(k as i32) * nj + floor,
                "{} k={k}: unroll err {ek} vs C·ρᵏ·‖J_imp v‖ = {} (rho {rho})",
                p.name,
                C * rho_bound.powi(k as i32) * nj
            );
            // ‖(∂₁T)ᵏ⁺¹w‖ ≤ ‖∂₁T‖·‖(∂₁T)ᵏw‖ and ‖∂₁T‖ ≤ 1: never grows.
            assert!(
                ek <= prev + 1e-9 * (1.0 + nj),
                "{} k={k}: unroll error grew ({ek} after {prev})",
                p.name
            );
            prev = ek;
            if k == 1 {
                e_first = ek;
            }
            e_last = ek;
        }
        // k = 1 is exactly one-step.
        assert!(
            (e_first - e1).abs() <= 1e-12 * (1.0 + e1),
            "{}: unroll(1) must equal one-step ({e_first} vs {e1})",
            p.name
        );
        // On a certified contraction the 16-term tail is a real improvement
        // (unless one-step was already at the floor).
        if rho < RHO_STRICT && e1 > 10.0 * floor {
            assert!(
                e_last <= 0.9 * e_first,
                "{}: unroll(16) {e_last} did not improve on one-step {e_first}",
                p.name
            );
        }
    }
}
