//! Packed-GEMM tail correctness: every kernel/blocking configuration must
//! agree with a naive triple loop on shapes that exercise the partial-tile
//! edges — m, k, n not divisible by MR/NR/KC, the degenerate 1×1, 1×n and
//! m×1 products, and n < NR (a single ragged column panel). The SIMD
//! microkernels write through a tail buffer on ragged tiles, so these are
//! exactly the shapes where a masking bug would hide.

#[cfg(target_arch = "x86_64")]
use idiff::linalg::mat::KernelKind;
use idiff::linalg::{gemm_config, GemmConfig, Mat};
use idiff::util::rng::Rng;
use idiff::util::testkit::{check, Gen};

/// Reference i-k-j triple loop — no packing, no blocking, no SIMD.
fn naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for l in 0..a.cols {
            let ail = a.at(i, l);
            for j in 0..b.cols {
                *c.at_mut(i, j) += ail * b.at(l, j);
            }
        }
    }
    c
}

/// Per-element agreement with a depth-scaled tolerance (different
/// summation orders accumulate different roundoff).
fn agrees(c: &Mat, r: &Mat, depth: usize) -> bool {
    let tol = 1e-13 * (depth as f64).max(1.0);
    c.data.iter().zip(&r.data).all(|(x, y)| {
        let s = x.abs().max(y.abs()).max(1.0);
        (x - y).abs() <= tol * s
    })
}

/// The scalar baseline, the autotuned pick, and (where the CPU allows)
/// both AVX2 kernels at deliberately awkward KC choices.
fn configs_under_test() -> Vec<GemmConfig> {
    let mut cfgs = vec![GemmConfig::scalar(), gemm_config()];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            cfgs.push(GemmConfig::of(KernelKind::Avx2_8x4, 64));
            cfgs.push(GemmConfig::of(KernelKind::Avx2_4x8, 40));
        }
    }
    cfgs
}

#[test]
fn tail_shapes_match_naive_for_every_kernel() {
    let mut rng = Rng::new(91);
    for cfg in configs_under_test() {
        let shapes = [
            (1, 1, 1),
            (1, 1, 5),
            (1, 9, 1),
            (1, 6, 11),
            (7, 1, 3),
            (3, 4, 1),
            (2, 3, 2),
            // ragged panels pinned to THIS config's tile sizes
            (cfg.nr + 1, 5, cfg.nr - 1),
            (cfg.mr - 1, 7, cfg.nr + 1),
            (2 * cfg.mr + 1, cfg.kc + 3, 3 * cfg.nr + 2),
            (cfg.mr, cfg.kc, cfg.nr),
            (13, 17, 19),
        ];
        for (m, k, n) in shapes {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = a.matmul_cfg(&b, cfg);
            let r = naive(&a, &b);
            assert!(agrees(&c, &r, k), "{cfg}: {m}x{k}x{n} disagrees with naive");
        }
    }
}

#[test]
fn random_shapes_match_naive_across_configs() {
    let gen: Gen<(usize, usize, usize, u64)> = Gen::new(|rng: &mut Rng| {
        (
            1 + (rng.uniform() * 33.0) as usize,
            1 + (rng.uniform() * 40.0) as usize,
            1 + (rng.uniform() * 33.0) as usize,
            (rng.uniform() * 1e9) as u64,
        )
    });
    check("gemm-tails-random", 92, 60, &gen, |&(m, k, n, seed)| {
        let mut rng = Rng::new(seed + 1);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let r = naive(&a, &b);
        // the default dispatch path AND every explicit config
        agrees(&a.matmul(&b), &r, k)
            && configs_under_test().iter().all(|&cfg| agrees(&a.matmul_cfg(&b, cfg), &r, k))
    });
}
