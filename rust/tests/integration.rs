//! Cross-module integration tests: end-to-end custom_root, bilevel
//! hypergradients vs finite differences, XLA runtime parity (skipped if
//! artifacts are absent), solver/fixed-point decoupling, and the server.

use idiff::bilevel;
use idiff::coordinator::experiments::fig4::{self, DiffFp, Solver};
use idiff::diff::root::{jacobian_via_root, CustomRoot};
use idiff::diff::spec::RootMap;
use idiff::linalg::solve::LinearSolveConfig;
use idiff::ml::ridge::{RidgeProblem, RidgeRoot};
use idiff::util::rng::Rng;

fn ridge() -> RidgeProblem {
    let (x, y) = idiff::data::regression::diabetes_like(80, 8, 11);
    RidgeProblem::new(x, y)
}

#[test]
fn custom_root_end_to_end_matches_closed_form() {
    let rp = ridge();
    let p = rp.dim();
    let theta = vec![2.0; p];
    let truth = rp.jacobian_closed_form(&theta);
    let cr = CustomRoot::new(RidgeRoot(&rp), |_i: &[f64], th: &[f64]| {
        rp.solve_closed_form_vec(th)
    });
    let x_star = cr.solve(&vec![0.0; p], &theta);
    let jac = cr.jacobian(&x_star, &theta);
    for i in 0..p {
        for j in 0..p {
            assert!((jac.at(i, j) - truth.at(i, j)).abs() < 1e-7);
        }
    }
}

#[test]
fn batched_jacobian_is_one_block_solve_and_matches_columns() {
    // The batching PR's acceptance property, end to end on ridge: dense
    // Jacobian assembly issues ONE block solve (not p column solves) and
    // matches the column-by-column reference path to 1e-8.
    use idiff::diff::root::jacobian_via_root_columns;
    use idiff::linalg::solve::counter;
    let rp = ridge();
    let p = rp.dim();
    let theta = vec![1.5; p];
    let x_star = rp.solve_closed_form_vec(&theta);
    let root = RidgeRoot(&rp);
    counter::reset();
    let j_block = jacobian_via_root(&root, &x_star, &theta);
    assert_eq!(counter::count(), 1, "dense Jacobian must be a single block solve");
    let j_cols = jacobian_via_root_columns(&root, &x_star, &theta);
    assert_eq!(counter::count(), 1 + p, "column path issues p independent solves");
    for i in 0..p {
        for j in 0..p {
            assert!(
                (j_block.at(i, j) - j_cols.at(i, j)).abs() < 1e-8,
                "({i},{j}): {} vs {}",
                j_block.at(i, j),
                j_cols.at(i, j)
            );
        }
    }
}

#[test]
fn hypergradient_matches_finite_differences() {
    // outer L(θ) = ½‖x*(θ)‖² through the ridge root.
    let rp = ridge();
    let p = rp.dim();
    let theta = vec![1.0; p];
    let x_star = rp.solve_closed_form_vec(&theta);
    let root = RidgeRoot(&rp);
    let g = bilevel::hypergrad_implicit(
        &root,
        &x_star,
        &theta,
        &x_star, // ∇_x L = x*
        &vec![0.0; p],
        &LinearSolveConfig::default(),
    );
    let h = 1e-5;
    for j in 0..p {
        let mut tp = theta.clone();
        tp[j] += h;
        let lp = 0.5
            * rp.solve_closed_form_vec(&tp)
                .iter()
                .map(|v| v * v)
                .sum::<f64>();
        let mut tm = theta.clone();
        tm[j] -= h;
        let lm = 0.5
            * rp.solve_closed_form_vec(&tm)
                .iter()
                .map(|v| v * v)
                .sum::<f64>();
        let fd = (lp - lm) / (2.0 * h);
        assert!((g[j] - fd).abs() < 1e-5, "j={j}: {} vs {fd}", g[j]);
    }
}

#[test]
fn solver_fixed_point_decoupling_on_svm() {
    // Fig. 4(c)'s core claim: BCD solutions differentiated with the MD and
    // PG fixed points give the same hypergradient, and it matches FD.
    let setup = fig4::setup(30, 12, 3, 10, 5);
    let theta = 1.0;
    let x_star = fig4::inner_solve(&setup, Solver::Bcd, theta, 800);
    let g_md = fig4::hypergrad_implicit(&setup, DiffFp::Mirror, &x_star, theta);
    let g_pg = fig4::hypergrad_implicit(&setup, DiffFp::ProjGrad, &x_star, theta);
    assert!(
        (g_md - g_pg).abs() < 2e-2 * g_md.abs().max(1.0),
        "MD {g_md} vs PG {g_pg}"
    );
    // FD ground truth through the (re-solved) inner problem, w.r.t. λ = ln θ
    let h = 1e-4;
    let loss_at = |lam: f64| {
        let th = lam.exp();
        let x = setup.svm.solve_bcd(th, 800);
        setup.svm.outer_loss(&setup.x_val, &setup.y_val, &x, th)
    };
    let fd = (loss_at(h) - loss_at(-h)) / (2.0 * h);
    assert!(
        (g_pg - fd).abs() < 5e-2 * fd.abs().max(1.0),
        "implicit {g_pg} vs fd {fd}"
    );
}

#[test]
fn unrolled_hypergrad_converges_to_implicit_on_svm() {
    let setup = fig4::setup(24, 10, 3, 8, 6);
    let theta = 1.0;
    let x_star = fig4::inner_solve(&setup, Solver::ProxGrad, theta, 4000);
    let g_imp = fig4::hypergrad_implicit(&setup, DiffFp::ProjGrad, &x_star, theta);
    // the PG step is conservative (Frobenius bound), so unrolling converges
    // slowly — the paper's core observation; the estimate must improve
    // monotonically with the unrolling horizon and approach the implicit one.
    let g_short = fig4::hypergrad_unroll(&setup, DiffFp::ProjGrad, theta, 50);
    let g_long = fig4::hypergrad_unroll(&setup, DiffFp::ProjGrad, theta, 30_000);
    assert!(
        (g_long - g_imp).abs() <= (g_short - g_imp).abs() + 1e-9,
        "long {g_long} short {g_short} implicit {g_imp}"
    );
    assert!(
        (g_long - g_imp).abs() < 5e-2 * g_imp.abs().max(1.0),
        "long {g_long} vs implicit {g_imp}"
    );
}

#[test]
fn xla_runtime_parity_if_artifacts_present() {
    let dir = idiff::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let rt = idiff::runtime::XlaRuntime::new(&dir).expect("runtime");
    let rp = idiff::coordinator::experiments::xla_parity::load_shared_problem(&dir).unwrap();
    let d = rp.dim();
    let native = RidgeRoot(&rp);
    let oracle = idiff::runtime::XlaRidgeRoot {
        rt: &rt,
        d,
        design: rp.x.data.clone(),
        targets: rp.y.clone(),
    };
    let mut rng = Rng::new(9);
    let x = rng.normal_vec(d);
    let theta: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.5, 2.0)).collect();
    let fa = native.eval_vec(&x, &theta);
    let fb = oracle.eval_vec(&x, &theta);
    let scale = fa.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for i in 0..d {
        assert!((fa[i] - fb[i]).abs() / scale < 1e-4, "i={i}: {} vs {}", fa[i], fb[i]);
    }
    // implicit jacobians agree at f32 precision
    let x_star = rp.solve_closed_form_vec(&theta);
    let ja = jacobian_via_root(&native, &x_star, &theta);
    let jb = jacobian_via_root(&oracle, &x_star, &theta);
    let jscale = ja.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for i in 0..ja.data.len() {
        assert!((ja.data[i] - jb.data[i]).abs() / jscale < 1e-3);
    }
}

#[test]
fn md_implicit_sensitivity_stable_unroll_not() {
    use idiff::coordinator::experiments::md_sens;
    use idiff::md::{random_packing, SoftSphereSystem};
    let n = 12;
    let theta = 0.6;
    let area = (n as f64 / 2.0) * (std::f64::consts::PI / 4.0) * (1.0 + theta * theta);
    let sys = SoftSphereSystem::new(n, (area / 1.25).sqrt());
    let mut rng = Rng::new(3);
    let x0 = random_packing(n, &mut rng);
    let cfg = idiff::solvers::fire::FireConfig {
        max_iter: 8000,
        force_tol: 1e-10,
        ..Default::default()
    };
    let x_star = sys.relax(&x0, theta, &cfg);
    let dx = md_sens::implicit_sensitivity(&sys, &x_star, theta);
    let n1 = idiff::linalg::vecops::norm1(&dx);
    assert!(n1.is_finite());
    // cross-check against FD of the relaxed positions (loose: FIRE restarts
    // can hop basins; require the right order of magnitude)
    let h = 1e-5;
    let xp = sys.relax(&x_star, theta + h, &cfg);
    let xm = sys.relax(&x_star, theta - h, &cfg);
    let fd: Vec<f64> = xp.iter().zip(&xm).map(|(a, b)| (a - b) / (2.0 * h)).collect();
    let n_fd = idiff::linalg::vecops::norm1(&fd);
    assert!(
        n1 < 50.0 * n_fd.max(1e-9) && n_fd < 50.0 * n1.max(1e-9),
        "implicit {n1} vs fd {n_fd}"
    );
}

#[test]
fn server_roundtrip_over_tcp() {
    use idiff::coordinator::serve::{ServeConfig, Server};
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = std::sync::Arc::new(Server::new(ServeConfig::default()));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    stream.write_all(b"{\"op\": \"ping\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\""), "{line}");
    // a catalog request end-to-end, twice: second reply must be cache-served
    let req = b"{\"op\":\"hypergrad\",\"problem\":\"quad\",\"theta\":[0.4,0.1,-0.2,0.9],\"v\":[1,0,0,0,0,0]}\n";
    for expect_cached in [false, true] {
        stream.write_all(req).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"grad\""), "{line}");
        assert!(
            line.contains(&format!("\"cached\":{expect_cached}")),
            "expected cached={expect_cached}: {line}"
        );
    }
    // malformed line keeps the connection usable
    stream.write_all(b"not json\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"error\""), "{line}");
    stream.write_all(b"{\"op\": \"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\""), "{line}");
}

#[test]
fn concurrent_tcp_clients_share_one_block_solve() {
    // The serve tentpole end-to-end over TCP: k clients firing hypergrads
    // at one (problem, θ) produce exactly one iterative block solve; a
    // repeat-θ client afterwards is served from the factorization cache
    // with zero new solves.
    use idiff::coordinator::serve::{ServeConfig, Server};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::Ordering;
    let n = 4;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = std::sync::Arc::new(Server::new(ServeConfig {
        batch_window: std::time::Duration::from_secs(10),
        batch_max: n,
        workers: n + 1,
        ..ServeConfig::default()
    }));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    let theta = "[1.0,1.0,1.0,1.0,1.0,1.0,1.0,1.0]";
    let handles: Vec<_> = (0..n)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = std::net::TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let v: Vec<String> =
                    (0..8).map(|j| if j == i { "1.0".into() } else { "0.0".into() }).collect();
                let req = format!(
                    "{{\"op\":\"hypergrad\",\"problem\":\"ridge\",\"theta\":{theta},\"v\":[{}]}}\n",
                    v.join(",")
                );
                stream.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.contains("\"grad\""), "{line}");
                assert!(line.contains(&format!("\"batched\":{n}")), "{line}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        server.stats.block_solves.load(Ordering::Relaxed),
        1,
        "k concurrent TCP hypergrads on one θ must coalesce into ONE block solve"
    );
    assert_eq!(server.stats.inner_solves.load(Ordering::Relaxed), 1);
    // repeat θ: factorization-cache hit, zero new solves
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let req = format!(
        "{{\"op\":\"hypergrad\",\"problem\":\"ridge\",\"theta\":{theta},\"v\":[1,1,1,1,1,1,1,1]}}\n"
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"cached\":true"), "{line}");
    assert_eq!(server.stats.block_solves.load(Ordering::Relaxed), 1, "repeat θ: no new solves");
    assert_eq!(server.stats.inner_solves.load(Ordering::Relaxed), 1);
}

#[test]
fn concurrent_auto_mode_on_cold_theta_is_solve_and_factorization_free() {
    // The one-step serve acceptance property end-to-end over TCP: k clients
    // firing `"mode":"auto"` hypergrads at a cold θ get one-step answers
    // from ONE shared inner solve — zero iterative block solves, zero
    // factorizations, zero dense materializations, θ-cache untouched. After
    // an implicit request warms the cache, auto flips to the factored
    // implicit path.
    use idiff::coordinator::serve::{ServeConfig, Server};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::Ordering;
    let n = 4;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = std::sync::Arc::new(Server::new(ServeConfig {
        batch_window: std::time::Duration::from_secs(10),
        batch_max: n,
        workers: n + 1,
        ..ServeConfig::default()
    }));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    let theta = "[1.3,1.3,1.3,1.3,1.3,1.3,1.3,1.3]";
    let handles: Vec<_> = (0..n)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = std::net::TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let v: Vec<String> =
                    (0..8).map(|j| if j == i { "1.0".into() } else { "0.0".into() }).collect();
                let req = format!(
                    "{{\"op\":\"hypergrad\",\"problem\":\"ridge\",\"theta\":{theta},\"v\":[{}],\"mode\":\"auto\"}}\n",
                    v.join(",")
                );
                stream.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.contains("\"grad\""), "{line}");
                assert!(line.contains(&format!("\"batched\":{n}")), "{line}");
                assert!(line.contains("\"cached\":false"), "{line}");
                assert!(line.contains("\"mode\":\"auto\""), "{line}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        server.stats.block_solves.load(Ordering::Relaxed),
        0,
        "auto on a cold contraction must answer without any iterative solve"
    );
    assert_eq!(server.stats.factorizations.load(Ordering::Relaxed), 0);
    assert_eq!(server.stats.densified.load(Ordering::Relaxed), 0);
    assert_eq!(
        server.stats.inner_solves.load(Ordering::Relaxed),
        1,
        "the batch leader solves the inner problem once for everyone"
    );
    // Warm the θ-cache through an implicit request…
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let req = format!(
        "{{\"op\":\"hypergrad\",\"problem\":\"ridge\",\"theta\":{theta},\"v\":[1,1,1,1,1,1,1,1]}}\n"
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"mode\":\"implicit\""), "{line}");
    assert_eq!(server.stats.factorizations.load(Ordering::Relaxed), 1);
    // …after which auto serves the factored implicit answer.
    let req = format!(
        "{{\"op\":\"hypergrad\",\"problem\":\"ridge\",\"theta\":{theta},\"v\":[1,1,1,1,1,1,1,1],\"mode\":\"auto\"}}\n"
    );
    stream.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"cached\":true"), "{line}");
    assert!(line.contains("\"mode\":\"implicit\""), "{line}");
    assert_eq!(
        server.stats.factorizations.load(Ordering::Relaxed),
        1,
        "the warm-cache auto path must not refactorize"
    );
}
