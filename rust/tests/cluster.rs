//! Sharded serving tier, end to end.
//!
//! Four layers of coverage:
//!
//! 1. **Ring property test** — growing/shrinking the member set moves only
//!    the arcs of the added/removed member (≈ 1/N of the keyspace), and the
//!    assignment is a pure function of the member set (deterministic across
//!    independently constructed rings — the property the router and the
//!    shard manifest slicer both rely on).
//! 2. **Engine-level admission** — a saturated solve lane rejects implicit
//!    (and cold-Jacobian) work with the canonical `overloaded` error,
//!    degrades `"mode":"auto"` requests with a cached contractive ρ to
//!    solve-free answers (flagged + counted), and never refuses cache hits
//!    or the control plane.
//! 3. **Both wires under pressure** — the overload reject and the degraded
//!    flag are identical across the JSON and binary protocols, and the
//!    `stats` op reports the same cluster fields on both.
//! 4. **Two shard processes + router process** — exactly one factorization
//!    per θ cluster-wide (zero duplicates), verbatim relaying on both
//!    wires, and failover after a shard kill without poisoning the
//!    survivor's cache. Plus SIGTERM graceful shutdown writing the
//!    warm-start manifest.

use idiff::coordinator::serve::cluster::ring::{Ring, DEFAULT_VNODES};
use idiff::coordinator::serve::wire::{self, ReplyFrame, RequestFrame};
use idiff::coordinator::serve::{ServeConfig, Server};
use idiff::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------- helpers --

fn quiet_cfg() -> ServeConfig {
    ServeConfig { batch_window: Duration::from_millis(0), ..ServeConfig::default() }
}

fn start(cfg: ServeConfig) -> (SocketAddr, Arc<Server>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = Arc::new(Server::new(cfg));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    (addr, server)
}

fn hypergrad_line(problem: &str, theta: &[f64], v: &[f64], mode: Option<&str>) -> String {
    let mut members = vec![
        ("op", Json::Str("hypergrad".to_string())),
        ("problem", Json::Str(problem.to_string())),
        ("theta", Json::arr_f64(theta)),
        ("v", Json::arr_f64(v)),
    ];
    if let Some(m) = mode {
        members.push(("mode", Json::Str(m.to_string())));
    }
    Json::obj(members).to_string_compact()
}

struct JsonClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl JsonClient {
    fn connect(addr: &str) -> JsonClient {
        let stream = TcpStream::connect(addr).expect("connect json");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        JsonClient { stream, reader }
    }

    fn request(&mut self, line: &str) -> Json {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        idiff::util::json::parse(reply.trim())
            .unwrap_or_else(|e| panic!("reply '{}' does not parse: {e}", reply.trim()))
    }
}

struct BinClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl BinClient {
    fn connect(addr: &str) -> BinClient {
        let stream = TcpStream::connect(addr).expect("connect bin");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        BinClient { stream, buf: Vec::new() }
    }

    fn request(&mut self, frame: &RequestFrame) -> ReplyFrame {
        self.buf.clear();
        wire::encode_request(frame, &mut self.buf);
        self.stream.write_all(&self.buf).unwrap();
        wire::read_reply(&mut self.stream).expect("read reply frame")
    }
}

fn vjp_frame<'a>(problem: &'a str, theta: &'a [f64], v: &'a [f64], mode: u8) -> RequestFrame<'a> {
    RequestFrame {
        opcode: wire::OP_VJP,
        mode,
        problem,
        theta,
        v,
        ..RequestFrame::control(wire::OP_VJP)
    }
}

// ----------------------------------------------------- 1. ring properties --

#[test]
fn ring_membership_changes_move_only_the_affected_arcs() {
    let keys: Vec<Vec<f64>> =
        (0..800).map(|i| (0..8).map(|j| 0.3 + i as f64 * 0.017 + j as f64 * 0.9).collect()).collect();
    for n in 2..=5u32 {
        let members: Vec<u32> = (0..n).collect();
        let grown: Vec<u32> = (0..=n).collect();
        let small = Ring::new(&members, DEFAULT_VNODES);
        let big = Ring::new(&grown, DEFAULT_VNODES);
        // Determinism: an independently built identical ring agrees everywhere.
        let small2 = Ring::new(&members, DEFAULT_VNODES);
        let mut moved = 0usize;
        for t in &keys {
            let before = small.shard_for("ridge", t).unwrap();
            assert_eq!(small2.shard_for("ridge", t).unwrap(), before);
            let after = big.shard_for("ridge", t).unwrap();
            if before != after {
                // Growth may only move keys TO the new member.
                assert_eq!(after, n, "key moved between surviving members on growth");
                moved += 1;
            }
        }
        // Expect ≈ keys/(n+1) moved; allow wide slack (the assignment is
        // deterministic, so this bound is about ring balance, not luck).
        let expect = keys.len() / (n as usize + 1);
        assert!(
            moved > expect / 3 && moved < expect * 3,
            "n={n}: moved {moved}, expected ≈{expect}"
        );
    }
}

// ------------------------------------------------- 2. engine-level admission --

#[test]
fn saturated_solve_lane_rejects_implicit_and_degrades_cached_auto() {
    let s = Server::new(quiet_cfg());
    let theta_warm = vec![1.1; 8];
    let theta_auto = vec![0.9; 8];
    let theta_cold = vec![1.7; 8];
    let v = vec![0.5; 8];

    // Warm one implicit θ (factored) and one auto ρ before applying pressure.
    let r = s.handle(&hypergrad_line("ridge", &theta_warm, &v, None));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    let r = s.handle(&hypergrad_line("ridge", &theta_auto, &v, Some("auto")));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    assert!(r.get("degraded").is_none(), "no pressure yet: {}", r.to_string_compact());
    let factorizations_before = s.stats.factorizations.load(Ordering::Relaxed);

    // Saturate the solve lane: limit 1, and hold that one slot.
    s.admission().set_max_solve_inflight(1);
    let hold = s.admission().solve_slot().expect("claim the only solve slot");

    // Implicit on a cold θ: canonical reject.
    let r = s.handle(&hypergrad_line("ridge", &theta_cold, &v, None));
    assert_eq!(r.to_string_compact(), r#"{"error":"overloaded"}"#);
    // Cold Jacobian rides the same lane.
    let jac = Json::obj(vec![
        ("op", Json::Str("jacobian".to_string())),
        ("problem", Json::Str("ridge".to_string())),
        ("theta", Json::arr_f64(&theta_cold)),
    ])
    .to_string_compact();
    assert_eq!(s.handle(&jac).to_string_compact(), r#"{"error":"overloaded"}"#);
    assert_eq!(s.admission().rejected(), 2);

    // Auto with a cached contractive ρ: served solve-free, flagged degraded.
    let r = s.handle(&hypergrad_line("ridge", &theta_auto, &v, Some("auto")));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    assert_eq!(r.get("degraded"), Some(&Json::Bool(true)));
    assert_eq!(s.admission().degraded_one_step(), 1);

    // Auto with a COLD ρ is not degraded (no cached estimate to lean on) —
    // it runs the ordinary solve-free path.
    let r = s.handle(&hypergrad_line("ridge", &[0.85; 8], &v, Some("auto")));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    assert!(r.get("degraded").is_none());
    assert_eq!(s.admission().degraded_one_step(), 1);

    // Cache hits and the control plane are always served under pressure.
    let r = s.handle(&hypergrad_line("ridge", &theta_warm, &v, None));
    assert_eq!(r.get("cached"), Some(&Json::Bool(true)));
    assert!(r.get("degraded").is_none());
    assert!(s.handle(r#"{"op":"stats"}"#).get("error").is_none());

    // No factorization happened under saturation…
    assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), factorizations_before);
    // …and releasing the slot restores the implicit path.
    drop(hold);
    let r = s.handle(&hypergrad_line("ridge", &theta_cold, &v, None));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
}

// -------------------------------------------- 3. both wires under pressure --

#[test]
fn overload_and_degrade_are_identical_on_both_wires() {
    let (addr, server) = start(quiet_cfg());
    let addr = addr.to_string();
    let mut jc = JsonClient::connect(&addr);
    let mut bc = BinClient::connect(&addr);
    let theta_auto = vec![0.9; 8];
    let theta_cold = vec![2.3; 8];
    let v = vec![0.5; 8];

    // Warm the ρ-cache, then saturate the solve lane.
    let r = jc.request(&hypergrad_line("ridge", &theta_auto, &v, Some("auto")));
    assert!(r.get("error").is_none());
    server.admission().set_max_solve_inflight(1);
    let _hold = server.admission().solve_slot().expect("claim the only solve slot");

    // Overload reject, both wires.
    let r = jc.request(&hypergrad_line("ridge", &theta_cold, &v, None));
    assert_eq!(r.to_string_compact(), r#"{"error":"overloaded"}"#);
    let f = bc.request(&vjp_frame("ridge", &theta_cold, &v, wire::MODE_IMPLICIT));
    assert_eq!(f.status, wire::STATUS_ERR);
    assert_eq!(f.error.as_deref(), Some("overloaded"));
    assert!(!f.degraded);

    // Degraded auto, both wires (flag in JSON, flag bit on the frame).
    let r = jc.request(&hypergrad_line("ridge", &theta_auto, &v, Some("auto")));
    assert_eq!(r.get("degraded"), Some(&Json::Bool(true)));
    let f = bc.request(&vjp_frame("ridge", &theta_auto, &v, wire::MODE_AUTO));
    assert_eq!(f.status, wire::STATUS_OK);
    assert!(f.degraded, "binary wire must carry FLAG_DEGRADED");
    assert_eq!(server.admission().degraded_one_step(), 2);

    // The cluster stats fields exist and agree across wires.
    let js = jc.request(r#"{"op":"stats"}"#);
    let bs = bc.request(&RequestFrame::control(wire::OP_STATS));
    let bjson = idiff::util::json::parse(&bs.text).expect("binary stats text parses");
    for key in [
        "shard_id",
        "shard_count",
        "ring_size",
        "solve_inflight",
        "queue_depth",
        "rejected",
        "degraded_one_step",
        "actor_restarts",
        "catalog_fingerprint",
    ] {
        assert_eq!(js.get(key), bjson.get(key), "stats field '{key}' differs across wires");
        assert!(js.get(key).is_some(), "stats field '{key}' missing");
    }
    assert_eq!(js.get("shard_id"), Some(&Json::Num(0.0)));
    assert_eq!(js.get("shard_count"), Some(&Json::Num(1.0)));
    assert_eq!(js.get("rejected"), Some(&Json::Num(2.0)));
    assert_eq!(js.get("degraded_one_step"), Some(&Json::Num(2.0)));
}

// -------------------------------------- 4. shard + router processes (e2e) --

struct Proc {
    child: Child,
    addr: String,
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_idiff(args: &[&str], listen_tag: &str) -> Proc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_idiff"))
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn idiff");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if reader.read_line(&mut line).expect("read child stdout") == 0 {
            panic!("{listen_tag} exited before announcing its address");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().expect("address token").to_string();
        }
    };
    // Keep draining stdout so the child never blocks on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    Proc { child, addr }
}

fn shard_rows(stats: &Json) -> Vec<(String, bool, f64)> {
    stats
        .get("shards")
        .and_then(Json::as_arr)
        .expect("router stats has a shards array")
        .iter()
        .map(|row| {
            (
                row.str_or("addr", "").to_string(),
                row.get("healthy") == Some(&Json::Bool(true)),
                row.get("stats")
                    .and_then(|s| s.get("factorizations"))
                    .and_then(Json::as_f64)
                    .unwrap_or(-1.0),
            )
        })
        .collect()
}

#[test]
fn two_shard_cluster_deduplicates_factorizations_and_fails_over() {
    let shard0 = spawn_idiff(
        &["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--window-ms", "0", "--shard", "0/2"],
        "shard 0",
    );
    let shard1 = spawn_idiff(
        &["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--window-ms", "0", "--shard", "1/2"],
        "shard 1",
    );
    let shards_arg = format!("{},{}", shard0.addr, shard1.addr);
    let router = spawn_idiff(
        &["route", "--addr", "127.0.0.1:0", "--workers", "2", "--health-secs", "1", "--shards", &shards_arg],
        "router",
    );

    let thetas: Vec<Vec<f64>> = (0..24).map(|i| vec![1.0 + 0.01 * i as f64; 8]).collect();
    let v = vec![0.5; 8];

    // 24 distinct θ, 3 passes each, through the router. First pass factors;
    // repeats must hit the owning shard's cache (proof the ring is sticky).
    let mut jc = JsonClient::connect(&router.addr);
    for pass in 0..3 {
        for t in &thetas {
            let r = jc.request(&hypergrad_line("ridge", t, &v, None));
            assert!(r.get("error").is_none(), "pass {pass}: {}", r.to_string_compact());
            if pass > 0 {
                assert_eq!(
                    r.get("cached"),
                    Some(&Json::Bool(true)),
                    "repeat-θ must be served from the owning shard's cache"
                );
            }
        }
    }
    let stats = jc.request(r#"{"op":"stats"}"#);
    let rows = shard_rows(&stats);
    assert_eq!(rows.len(), 2);
    assert!(rows.iter().all(|(_, healthy, _)| *healthy));
    let (f0, f1) = (rows[0].2, rows[1].2);
    assert!(f0 > 0.0 && f1 > 0.0, "ring left a shard idle: {f0}/{f1}");
    assert_eq!(
        f0 + f1,
        thetas.len() as f64,
        "exactly one factorization per θ cluster-wide (zero duplicates)"
    );

    // Same cluster, binary wire: repeats stay cached, no new factorizations.
    let mut bc = BinClient::connect(&router.addr);
    for t in thetas.iter().take(6) {
        let f = bc.request(&vjp_frame("ridge", t, &v, wire::MODE_IMPLICIT));
        assert_eq!(f.status, wire::STATUS_OK);
        assert!(f.cached, "binary repeat-θ through the router must be cached");
    }
    let bs = bc.request(&RequestFrame::control(wire::OP_STATS));
    let brows = shard_rows(&idiff::util::json::parse(&bs.text).unwrap());
    assert_eq!(brows[0].2 + brows[1].2, thetas.len() as f64);

    // Kill shard 0: its arcs re-hash onto shard 1 (cold start there, one
    // factorization per migrated θ), shard-1-native θ's stay cached — the
    // survivor's cache is not poisoned.
    drop(shard0);
    let mut jc = JsonClient::connect(&router.addr);
    for t in &thetas {
        let r = jc.request(&hypergrad_line("ridge", t, &v, None));
        assert!(r.get("error").is_none(), "failover: {}", r.to_string_compact());
    }
    let stats = jc.request(r#"{"op":"stats"}"#);
    let rows = shard_rows(&stats);
    assert!(!rows[0].1, "killed shard must be marked unhealthy");
    assert_eq!(
        rows[1].2,
        f1 + f0,
        "survivor re-factors exactly the migrated θ's, keeps its own cache"
    );
    let failovers =
        stats.get("failovers").and_then(Json::as_f64).expect("router reports failovers");
    assert!(failovers >= 1.0);
    drop(jc);
    drop(router);
    drop(shard1);
}

/// Reserve two distinct loopback ports by binding both before dropping
/// either. The shard processes need to know each other's address up front
/// (`--peers` is index-aligned with shard ids), so `--addr 127.0.0.1:0`
/// self-assignment is not an option here.
fn reserve_two_ports() -> (u16, u16) {
    let a = TcpListener::bind("127.0.0.1:0").expect("reserve port a");
    let b = TcpListener::bind("127.0.0.1:0").expect("reserve port b");
    (a.local_addr().unwrap().port(), b.local_addr().unwrap().port())
}

/// One numeric field out of a shard's own `stats` reply (fresh connection
/// per call so the poll below never observes a stale pipelined reply).
fn shard_stat(addr: &str, key: &str) -> f64 {
    let mut c = JsonClient::connect(addr);
    c.request(r#"{"op":"stats"}"#).get(key).and_then(Json::as_f64).unwrap_or(-1.0)
}

#[test]
fn killed_shard_fails_over_onto_its_warm_replica_with_zero_new_factorizations() {
    let (pa, pb) = reserve_two_ports();
    let addr_a = format!("127.0.0.1:{pa}");
    let addr_b = format!("127.0.0.1:{pb}");
    let peers = format!("{addr_a},{addr_b}");
    let shard0 = spawn_idiff(
        &[
            "serve", "--addr", &addr_a, "--workers", "2", "--window-ms", "0",
            "--shard", "0/2", "--peers", &peers, "--replicate-secs", "1",
        ],
        "shard 0",
    );
    let shard1 = spawn_idiff(
        &[
            "serve", "--addr", &addr_b, "--workers", "2", "--window-ms", "0",
            "--shard", "1/2", "--peers", &peers, "--replicate-secs", "1",
        ],
        "shard 1",
    );
    let router = spawn_idiff(
        &["route", "--addr", "127.0.0.1:0", "--workers", "2", "--health-secs", "1", "--shards", &peers],
        "router",
    );

    // Warm 24 distinct θ's through the router and keep every grad verbatim.
    let thetas: Vec<Vec<f64>> = (0..24).map(|i| vec![1.0 + 0.01 * i as f64; 8]).collect();
    let v = vec![0.5; 8];
    let mut jc = JsonClient::connect(&router.addr);
    let mut first_grads: Vec<Vec<Json>> = Vec::new();
    for t in &thetas {
        let r = jc.request(&hypergrad_line("ridge", t, &v, None));
        assert!(r.get("error").is_none(), "warmup: {}", r.to_string_compact());
        first_grads.push(r.get("grad").and_then(Json::as_arr).expect("grad").to_vec());
    }
    let f0 = shard_stat(&shard0.addr, "factorizations");
    let f1 = shard_stat(&shard1.addr, "factorizations");
    assert!(f0 > 0.0 && f1 > 0.0, "ring left a shard idle: {f0}/{f1}");
    assert_eq!(f0 + f1, thetas.len() as f64, "one factorization per θ cluster-wide");

    // Wait for the 1-second replicator to ship each shard's owned slice to
    // its ring successor (the other shard). Facts ship before ρ entries, so
    // `replicated_in >= peer facts` means every factorization has landed.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let into_1 = shard_stat(&shard1.addr, "replicated_in");
        let into_0 = shard_stat(&shard0.addr, "replicated_in");
        if into_1 >= f0 && into_0 >= f1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "replication never completed: shard1 got {into_1}/{f0}, shard0 got {into_0}/{f1}"
        );
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(shard_stat(&shard0.addr, "replicated_out") >= f0);

    // SIGKILL shard 0. Its arcs re-hash onto shard 1, which must serve the
    // migrated θ's FROM THE REPLICA: every answer cached, bitwise-identical
    // to the pre-kill grad, and not a single new factorization.
    drop(shard0);
    let mut jc = JsonClient::connect(&router.addr);
    for (t, want) in thetas.iter().zip(&first_grads) {
        let r = jc.request(&hypergrad_line("ridge", t, &v, None));
        assert!(r.get("error").is_none(), "failover: {}", r.to_string_compact());
        assert_eq!(
            r.get("cached"),
            Some(&Json::Bool(true)),
            "failover must land on the warm replica, not re-factor: {}",
            r.to_string_compact()
        );
        assert_eq!(
            r.get("grad").and_then(Json::as_arr).expect("grad"),
            want.as_slice(),
            "replicated answer must be bitwise-identical to the original"
        );
    }
    assert_eq!(
        shard_stat(&shard1.addr, "factorizations"),
        f1,
        "warm failover must cost zero new factorizations"
    );

    // The router agrees: breaker open on the dead shard, survivor untouched.
    let stats = jc.request(r#"{"op":"stats"}"#);
    let rows = shard_rows(&stats);
    assert!(!rows[0].1, "killed shard must be marked unhealthy");
    assert_eq!(rows[1].2, f1, "router sees the survivor's factorizations unchanged");
    drop(jc);
    drop(router);
    drop(shard1);
}

#[cfg(unix)]
#[test]
fn sigterm_writes_the_warm_start_manifest_before_exit() {
    let manifest =
        std::env::temp_dir().join(format!("idiff_cluster_sigterm_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&manifest);
    let manifest_str = manifest.to_str().unwrap().to_string();
    let mut server = spawn_idiff(
        &[
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--window-ms",
            "0",
            "--persist-secs",
            "0",
            "--manifest",
            &manifest_str,
        ],
        "server",
    );
    let mut jc = JsonClient::connect(&server.addr);
    let r = jc.request(&hypergrad_line("ridge", &[1.25; 8], &[0.5; 8], None));
    assert!(r.get("error").is_none(), "{}", r.to_string_compact());
    assert!(!manifest.exists(), "manifest must not exist before shutdown (persist-secs 0)");

    let pid = server.child.id().to_string();
    assert!(Command::new("kill")
        .args(["-s", "TERM", &pid])
        .status()
        .expect("send SIGTERM")
        .success());
    let status = server.child.wait().expect("child exit");
    assert!(status.success(), "graceful shutdown must exit 0, got {status}");

    let text = std::fs::read_to_string(&manifest).expect("SIGTERM must write the manifest");
    let doc = idiff::util::json::parse(&text).expect("manifest parses");
    let entries = doc.get("entries").and_then(Json::as_arr).expect("entries array");
    assert_eq!(entries.len(), 1, "one factored θ was live at shutdown");
    let _ = std::fs::remove_file(&manifest);
}
