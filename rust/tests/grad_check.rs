//! Catalog-wide derivative sweep (the "trust every oracle" test):
//!
//! 1. For every catalog mapping — ridge, logreg, SVM, prox-grad/lasso,
//!    projected-GD, stationary quadratic — the analytic `jvp_x`/`jvp_theta`
//!    are checked against `ad::num_grad` central differences on randomized
//!    (x, θ) drawn through `util::testkit`, `vjp_*` are checked through the
//!    adjoint identity, and every batch override (`jvp_x_batch` etc.) is
//!    checked against its column loop.
//! 2. Projection property tests: idempotence, feasibility and
//!    non-expansiveness on random inputs for the simplex, ℓ1/ℓ2/ℓ∞ balls,
//!    boxes and affine sets.
//! 3. Unroll↔implicit consistency (the Fig. 3 claim as a regression test):
//!    forward-mode unrolling of a contraction at a large iteration count
//!    agrees with `implicit_jvp`.
//!
//! Piecewise-smooth mappings (prox/projection fixed points) are sampled
//! away from their kinks: a draw where forward and backward one-sided
//! differences disagree is skipped rather than compared against a
//! meaningless central difference.

use idiff::diff::precision::{check_bound, ridge_constants, select_precision, ErrorPair};
use idiff::diff::root::{
    implicit_jvp, implicit_vjp, jacobian_via_root, jacobian_via_root_columns,
    FACTORIZE_DENSE_LIMIT,
};
use idiff::diff::spec::{FixedPointResidual, RootMap};
use idiff::linalg::op::densify;
use idiff::linalg::solve::{LinearSolveConfig, SolvePrecision};
use idiff::linalg::{vecops, CsrMat, Mat};
use idiff::mappings::objective::QuadObjective;
use idiff::mappings::prox_grad::{ProjGradFixedPoint, ProxGradFixedPoint};
use idiff::mappings::stationary::{GradientDescentFixedPoint, StationaryMapping};
use idiff::ml::logreg::LogRegProblem;
use idiff::ml::ridge::{RidgeProblem, RidgeRoot};
use idiff::ml::svm::MulticlassSvm;
use idiff::proj::affine::AffineProjection;
use idiff::proj::balls::{L1BallProjection, L2BallProjection, LInfBallProjection};
use idiff::proj::boxes::{BoxProjection, NonNegProjection};
use idiff::proj::simplex::SimplexProjection;
use idiff::proj::Projection;
use idiff::prox::LassoProx;
use idiff::util::rng::Rng;
use idiff::util::testkit::{check, fd_jvp, Gen};

// ------------------------------------------------------------- helpers --

fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
    let scale = a.iter().chain(b).fold(1.0f64, |m, v| m.max(v.abs()));
    a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * scale)
}

/// The full oracle sweep for one RootMap at one randomized draw:
/// jvp_x/jvp_theta vs trusted FD, vjp_x/vjp_theta via the adjoint identity,
/// and all four batch overrides vs their column loops. Returns false on a
/// genuine mismatch, true when the draw passes (or straddles a kink).
fn sweep_draw(m: &dyn RootMap, x: &[f64], theta: &[f64], dir_seed: u64, fd_tol: f64) -> bool {
    let (d, n) = (m.dim_x(), m.dim_theta());
    let mut rng = Rng::new(dir_seed);
    let v_x = rng.normal_vec(d);
    let v_t = rng.normal_vec(n);
    let u = rng.normal_vec(d);

    // A derivative jump smaller than half the comparison tolerance cannot
    // fail the check (central differencing averages the two sides), and a
    // larger one flags the draw as a kink — so the two thresholds couple.
    let kink_tol = 0.5 * fd_tol;

    // jvp_x vs FD in x
    let mut jx = vec![0.0; d];
    m.jvp_x(x, theta, &v_x, &mut jx);
    match fd_jvp(|xx| m.eval_vec(xx, theta), x, &v_x, 1e-6, kink_tol) {
        Some(fd) => {
            if !close(&jx, &fd, fd_tol) {
                eprintln!("jvp_x mismatch:\n  analytic {jx:?}\n  fd       {fd:?}");
                return false;
            }
        }
        None => return true, // kink draw: skip the whole case
    }

    // jvp_theta vs FD in θ
    let mut jt = vec![0.0; d];
    m.jvp_theta(x, theta, &v_t, &mut jt);
    match fd_jvp(|tt| m.eval_vec(x, tt), theta, &v_t, 1e-6, kink_tol) {
        Some(fd) => {
            if !close(&jt, &fd, fd_tol) {
                eprintln!("jvp_theta mismatch:\n  analytic {jt:?}\n  fd       {fd:?}");
                return false;
            }
        }
        None => return true,
    }

    // vjp_x / vjp_theta via adjoint identities (analytic ↔ analytic, tight)
    let mut vx = vec![0.0; d];
    m.vjp_x(x, theta, &u, &mut vx);
    let lhs = vecops::dot(&u, &jx);
    let rhs = vecops::dot(&vx, &v_x);
    let s = lhs.abs().max(rhs.abs()).max(1.0);
    if (lhs - rhs).abs() > 1e-8 * s {
        eprintln!("vjp_x adjoint identity broken: {lhs} vs {rhs}");
        return false;
    }
    let mut vt = vec![0.0; n];
    m.vjp_theta(x, theta, &u, &mut vt);
    let lhs = vecops::dot(&u, &jt);
    let rhs = vecops::dot(&vt, &v_t);
    let s = lhs.abs().max(rhs.abs()).max(1.0);
    if (lhs - rhs).abs() > 1e-8 * s {
        eprintln!("vjp_theta adjoint identity broken: {lhs} vs {rhs}");
        return false;
    }

    // batch overrides vs their column loops (exact analytic paths)
    let k = 3;
    let vxb = Mat::randn(d, k, &mut rng);
    let vtb = Mat::randn(n, k, &mut rng);
    let mut col_in = vec![0.0; d.max(n)];
    let mut col_out = vec![0.0; d.max(n)];
    let mut out = Mat::zeros(d, k);
    m.jvp_x_batch(x, theta, &vxb, &mut out);
    for j in 0..k {
        vxb.col_into(j, &mut col_in[..d]);
        m.jvp_x(x, theta, &col_in[..d], &mut col_out[..d]);
        for i in 0..d {
            if (out.at(i, j) - col_out[i]).abs() > 1e-8 * (1.0 + col_out[i].abs()) {
                eprintln!("jvp_x_batch ({i},{j}): {} vs {}", out.at(i, j), col_out[i]);
                return false;
            }
        }
    }
    let mut out = Mat::zeros(d, k);
    m.vjp_x_batch(x, theta, &vxb, &mut out);
    for j in 0..k {
        vxb.col_into(j, &mut col_in[..d]);
        m.vjp_x(x, theta, &col_in[..d], &mut col_out[..d]);
        for i in 0..d {
            if (out.at(i, j) - col_out[i]).abs() > 1e-8 * (1.0 + col_out[i].abs()) {
                eprintln!("vjp_x_batch ({i},{j}): {} vs {}", out.at(i, j), col_out[i]);
                return false;
            }
        }
    }
    let mut out = Mat::zeros(d, k);
    m.jvp_theta_batch(x, theta, &vtb, &mut out);
    for j in 0..k {
        vtb.col_into(j, &mut col_in[..n]);
        m.jvp_theta(x, theta, &col_in[..n], &mut col_out[..d]);
        for i in 0..d {
            if (out.at(i, j) - col_out[i]).abs() > 1e-8 * (1.0 + col_out[i].abs()) {
                eprintln!("jvp_theta_batch ({i},{j}): {} vs {}", out.at(i, j), col_out[i]);
                return false;
            }
        }
    }
    let mut out = Mat::zeros(n, k);
    m.vjp_theta_batch(x, theta, &vxb, &mut out);
    for j in 0..k {
        vxb.col_into(j, &mut col_in[..d]);
        m.vjp_theta(x, theta, &col_in[..d], &mut col_out[..n]);
        for i in 0..n {
            if (out.at(i, j) - col_out[i]).abs() > 1e-8 * (1.0 + col_out[i].abs()) {
                eprintln!("vjp_theta_batch ({i},{j}): {} vs {}", out.at(i, j), col_out[i]);
                return false;
            }
        }
    }
    true
}

/// Run the sweep over `cases` randomized (x, θ) draws via testkit.
fn sweep_mapping<F>(name: &str, m: &dyn RootMap, seed: u64, cases: usize, fd_tol: f64, theta_gen: F)
where
    F: Fn(&mut Rng) -> Vec<f64> + 'static,
{
    let d = m.dim_x();
    let gen: Gen<(Vec<f64>, Vec<f64>)> =
        Gen::new(move |rng: &mut Rng| (rng.normal_vec(d), theta_gen(rng)));
    check(name, seed, cases, &gen, |(x, theta)| {
        // direction seed derived from the draw itself (prop must be Fn)
        let dir = seed ^ x[0].to_bits().rotate_left(13) ^ theta[0].to_bits();
        sweep_draw(m, x, theta, dir, fd_tol)
    });
}

fn random_quad(d: usize, n: usize, seed: u64) -> QuadObjective {
    let mut rng = Rng::new(seed);
    QuadObjective {
        q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0),
        r: Mat::randn(d, n, &mut rng),
        c: rng.normal_vec(d),
    }
}

// --------------------------------------------- 1. the derivative sweep --

#[test]
fn sweep_ridge_root() {
    let (x, y) = idiff::data::regression::diabetes_like(40, 6, 5);
    let rp = RidgeProblem::new(x, y);
    let root = RidgeRoot(&rp);
    sweep_mapping("ridge-root", &root, 101, 12, 2e-4, |rng| {
        (0..6).map(|_| rng.uniform_in(0.2, 2.0)).collect()
    });
}

#[test]
fn sweep_logreg_stationary() {
    let mut rng = Rng::new(6);
    let ds = idiff::data::classification::make_classification(16, 4, 3, 0.3, 2.0, &mut rng);
    let m = StationaryMapping::new(LogRegProblem::new(ds.x, ds.labels, 3));
    sweep_mapping("logreg-stationary", &m, 102, 10, 2e-4, |rng| {
        vec![rng.uniform_in(0.2, 1.5)]
    });
}

#[test]
fn sweep_svm_stationary() {
    let mut rng = Rng::new(7);
    let ds = idiff::data::classification::make_classification(10, 5, 3, 0.3, 2.0, &mut rng);
    let y = ds.one_hot();
    let m = StationaryMapping::new(MulticlassSvm::new(ds.x, y));
    sweep_mapping("svm-stationary", &m, 103, 8, 5e-4, |rng| {
        vec![rng.uniform_in(0.6, 1.8)]
    });
}

#[test]
fn sweep_prox_grad_lasso() {
    let t = ProxGradFixedPoint::new(random_quad(6, 2, 8), LassoProx { d: 6 }, 0.08);
    let res = FixedPointResidual(t);
    sweep_mapping("prox-grad-lasso", &res, 104, 20, 5e-4, |rng| {
        vec![rng.normal(), rng.normal(), rng.uniform_in(0.1, 0.8)]
    });
}

#[test]
fn sweep_proj_grad_simplex() {
    let t = ProjGradFixedPoint::new(random_quad(5, 2, 9), SimplexProjection { d: 5 }, 0.08);
    let res = FixedPointResidual(t);
    sweep_mapping("proj-grad-simplex", &res, 105, 20, 5e-4, |rng| {
        vec![rng.normal(), rng.normal()]
    });
}

#[test]
fn sweep_stationary_quad() {
    let m = StationaryMapping::new(random_quad(6, 3, 10));
    sweep_mapping("stationary-quad", &m, 106, 12, 2e-4, |rng| rng.normal_vec(3));
}

#[test]
fn sweep_gd_fixed_point_residual() {
    // Eq. 5: the GD fixed point's residual must carry the same derivative
    // structure for any η.
    let fp = GradientDescentFixedPoint { obj: random_quad(5, 2, 11), eta: 0.07 };
    let res = FixedPointResidual(fp);
    sweep_mapping("gd-fixed-point", &res, 107, 10, 2e-4, |rng| rng.normal_vec(2));
}

// ------------------------------------------ 2. projection properties --

/// Idempotence + non-expansiveness for any projection, via testkit pairs.
fn proj_properties<P: Projection>(
    name: &str,
    p: &P,
    theta: Vec<f64>,
    seed: u64,
    feasible: impl Fn(&[f64], &[f64]) -> bool,
) {
    let d = p.dim();
    let gen: Gen<(Vec<f64>, Vec<f64>)> =
        Gen::new(move |rng: &mut Rng| (rng.normal_vec(d), rng.normal_vec(d)));
    let theta2 = theta.clone();
    check(&format!("{name}-idempotent-feasible"), seed, 60, &gen, |(a, _)| {
        let z = p.project_vec(&scale3(a), &theta2);
        if !feasible(&z, &theta2) {
            eprintln!("{name}: infeasible output {z:?}");
            return false;
        }
        let zz = p.project_vec(&z, &theta2);
        vecops::rel_err(&zz, &z) < 1e-9
    });
    let theta2 = theta.clone();
    check(&format!("{name}-nonexpansive"), seed + 1, 60, &gen, |(a, b)| {
        let (a, b) = (scale3(a), scale3(b));
        let pa = p.project_vec(&a, &theta2);
        let pb = p.project_vec(&b, &theta2);
        let num = vecops::norm2(&vecops::sub(&pa, &pb));
        let den = vecops::norm2(&vecops::sub(&a, &b));
        num <= den + 1e-9
    });
}

/// Stretch draws so they land both inside and (mostly) outside small sets.
fn scale3(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| 3.0 * x).collect()
}

#[test]
fn projection_properties_hold() {
    proj_properties(
        "simplex",
        &SimplexProjection { d: 6 },
        vec![],
        201,
        |z, _| (z.iter().sum::<f64>() - 1.0).abs() < 1e-9 && z.iter().all(|&v| v >= -1e-12),
    );
    proj_properties("l2-ball", &L2BallProjection { d: 6 }, vec![1.4], 202, |z, t| {
        vecops::norm2(z) <= t[0] + 1e-9
    });
    proj_properties("l1-ball", &L1BallProjection { d: 6 }, vec![1.2], 203, |z, t| {
        vecops::norm1(z) <= t[0] + 1e-9
    });
    proj_properties("linf-ball", &LInfBallProjection { d: 6 }, vec![0.9], 204, |z, t| {
        vecops::norm_inf(z) <= t[0] + 1e-12
    });
    proj_properties("box", &BoxProjection { d: 6 }, vec![-0.5, 1.25], 205, |z, t| {
        z.iter().all(|&v| v >= t[0] - 1e-12 && v <= t[1] + 1e-12)
    });
    proj_properties("nonneg", &NonNegProjection { d: 6 }, vec![], 206, |z, _| {
        z.iter().all(|&v| v >= 0.0)
    });
    let mut rng = Rng::new(207);
    let a = Mat::randn(2, 6, &mut rng);
    let b = rng.normal_vec(2);
    let amat = a.clone();
    proj_properties("affine", &AffineProjection::new(a), b, 208, move |z, t| {
        let r = amat.matvec(z);
        r.iter().zip(t).all(|(ri, ti)| (ri - ti).abs() < 1e-8)
    });
}

// ------------------------------ 3. unroll ↔ implicit consistency --

#[test]
fn unroll_jvp_converges_to_implicit_jvp() {
    // Contraction: GD fixed point on a strongly convex quadratic with
    // η < 1/λ_max. Unrolling the tangent recursion to stationarity must
    // reproduce the implicit-function-theorem derivative (Fig. 3).
    let quad = random_quad(6, 3, 12);
    // power iteration for λ_max(Q)
    let mut v = vec![1.0; 6];
    let mut lam = 1.0;
    for _ in 0..100 {
        let mut w = quad.q.matvec(&v);
        lam = vecops::norm2(&w).max(1e-30);
        for wi in w.iter_mut() {
            *wi /= lam;
        }
        v = w;
    }
    let eta = 0.9 / lam;
    let theta = vec![0.4, -0.8, 1.1];
    let v_theta = vec![1.0, -0.5, 0.25];
    let fp = GradientDescentFixedPoint { obj: random_quad(6, 3, 12), eta };
    let (x_unroll, dx_unroll) =
        idiff::unroll::unroll_jvp(&fp, &vec![0.0; 6], &theta, &v_theta, 6000);
    let res = FixedPointResidual(GradientDescentFixedPoint { obj: random_quad(6, 3, 12), eta });
    let (dx_impl, rep) =
        implicit_jvp(&res, &x_unroll, &theta, &v_theta, &LinearSolveConfig::default());
    assert!(rep.converged);
    assert!(
        close(&dx_unroll, &dx_impl, 1e-6),
        "unrolled {dx_unroll:?} vs implicit {dx_impl:?}"
    );
    // …and a short horizon is measurably further away (the Fig. 3 shape).
    let (_, dx_short) = idiff::unroll::unroll_jvp(&fp, &vec![0.0; 6], &theta, &v_theta, 5);
    let err_long = vecops::norm2(&vecops::sub(&dx_unroll, &dx_impl));
    let err_short = vecops::norm2(&vecops::sub(&dx_short, &dx_impl));
    assert!(err_short > 10.0 * err_long.max(1e-12), "short {err_short} vs long {err_long}");
}

// ------------- 3b. three-mode equivalence (implicit / unroll / one-step) --

/// One catalog fixed-point map through all three derivative modes at its
/// converged x*: the Neumann JVP/VJP pair satisfies the adjoint identity
/// EXACTLY for every truncation k; one-step (k = 1) is refereed against the
/// kink-aware FD oracle on ∂₂T; and both solve-free modes land within the
/// Bolte-style contraction bounds of the implicit answer — O(ρ) for
/// one-step, O(ρᵏ) and non-increasing for unroll(k).
fn mode_equivalence_case<T: idiff::diff::spec::FixedPointMap>(
    name: &str,
    t: T,
    theta: &[f64],
    x0: &[f64],
    fd_tol: f64,
    dir_seed: u64,
) {
    use idiff::diff::spec::FixedPointMap;
    use idiff::diff::{estimate_contraction, neumann_jvp, neumann_vjp, one_step_jvp};
    let d = t.dim_x();
    let n = t.dim_theta();
    // Converge x* by iterating the map itself (it contracts by fixture
    // construction, so this is also a convergence check).
    let mut x = x0.to_vec();
    let mut nx = vec![0.0; d];
    for _ in 0..60_000 {
        t.eval(&x, theta, &mut nx);
        let delta = vecops::norm2(&vecops::sub(&x, &nx));
        std::mem::swap(&mut x, &mut nx);
        if delta < 1e-14 {
            break;
        }
    }
    let x_star = x;
    let mut rng = Rng::new(dir_seed);
    let v_t = rng.normal_vec(n);
    let u = rng.normal_vec(d);

    let rho = estimate_contraction(&t, &x_star, theta, 60, 0xabc);
    assert!(rho.is_finite() && rho < 1.0, "{name}: rho = {rho}");

    // Adjoint identity ⟨u, J_k v⟩ = ⟨J_kᵀ u, v⟩ — exact (same finite sum
    // transposed, no solver in sight), for every truncation depth.
    for k in [1usize, 3, 7] {
        let jv = neumann_jvp(&t, &x_star, theta, &v_t, k);
        let ju = neumann_vjp(&t, &x_star, theta, &u, k);
        let lhs = vecops::dot(&u, &jv);
        let rhs = vecops::dot(&ju, &v_t);
        assert!(
            (lhs - rhs).abs() <= 1e-10 * lhs.abs().max(rhs.abs()).max(1.0),
            "{name} k={k}: adjoint identity {lhs} vs {rhs}"
        );
    }

    // One-step IS ∂₂T — referee it against the shared kink-aware FD oracle
    // (a draw straddling a prox/projection kink is skipped, same policy as
    // the RootMap sweeps).
    let os = one_step_jvp(&t, &x_star, theta, &v_t);
    if let Some(fd) = fd_jvp(|tt| t.eval_vec(&x_star, tt), theta, &v_t, 1e-6, 0.5 * fd_tol) {
        assert!(
            close(&os, &fd, fd_tol),
            "{name}: one-step jvp vs fd\n  {os:?}\n  {fd:?}"
        );
    }

    // Contraction bounds against the implicit-diff answer. NormalCg handles
    // the non-symmetric PG/prox residuals (same choice as the registry).
    let res = FixedPointResidual(t);
    let cfg = LinearSolveConfig {
        kind: idiff::linalg::solve::LinearSolverKind::NormalCg,
        tol: 1e-11,
        max_iter: 4000,
        ..Default::default()
    };
    let (jv_imp, rep) = implicit_jvp(&res, &x_star, theta, &v_t, &cfg);
    assert!(rep.converged, "{name}: implicit solve {rep:?}");
    let nj = vecops::norm2(&jv_imp);
    let err_vs_imp = |a: &[f64]| vecops::norm2(&vecops::sub(a, &jv_imp));
    let e1 = err_vs_imp(&os);
    // slack 1.15: the power-iteration ρ̂ approaches σ_max(∂₁T) from below
    assert!(
        e1 <= 1.15 * rho * nj + 1e-9,
        "{name}: one-step err {e1} vs bound rho {rho} · ‖Jv‖ {nj}"
    );
    let mut prev = f64::INFINITY;
    for k in [1usize, 2, 4, 8, 16] {
        let jk = neumann_jvp(&res.0, &x_star, theta, &v_t, k);
        let ek = err_vs_imp(&jk);
        assert!(
            ek <= 1.15 * rho.powi(k as i32) * nj + 1e-9,
            "{name} k={k}: err {ek} vs rho^k bound (rho {rho}, ‖Jv‖ {nj})"
        );
        assert!(ek <= prev + 1e-12, "{name} k={k}: unroll error must not grow");
        prev = ek;
    }
}

/// λ_max by power iteration — fixture step sizes must actually contract.
fn lambda_max(q: &Mat) -> f64 {
    let mut v = vec![1.0; q.rows];
    let mut lam = 1.0;
    for _ in 0..100 {
        let mut w = q.matvec(&v);
        lam = vecops::norm2(&w).max(1e-30);
        for wi in w.iter_mut() {
            *wi /= lam;
        }
        v = w;
    }
    lam
}

#[test]
fn three_mode_equivalence_gd_quadratic() {
    let quad = random_quad(6, 3, 41);
    let eta = 0.9 / lambda_max(&quad.q);
    let fp = GradientDescentFixedPoint { obj: quad, eta };
    mode_equivalence_case("gd-quad", fp, &[0.4, -0.8, 1.1], &[0.0; 6], 2e-4, 0x3a01);
}

#[test]
fn three_mode_equivalence_prox_grad_lasso() {
    let quad = random_quad(6, 2, 42);
    let eta = 0.9 / lambda_max(&quad.q);
    let t = ProxGradFixedPoint::new(quad, LassoProx { d: 6 }, eta);
    mode_equivalence_case("prox-lasso", t, &[0.3, -0.4, 0.25], &[0.0; 6], 5e-4, 0x3a02);
}

#[test]
fn three_mode_equivalence_proj_grad_simplex() {
    let quad = random_quad(5, 2, 43);
    let eta = 0.9 / lambda_max(&quad.q);
    let t = ProjGradFixedPoint::new(quad, SimplexProjection { d: 5 }, eta);
    mode_equivalence_case("proj-simplex", t, &[0.6, -0.2], &[0.2; 5], 5e-4, 0x3a03);
}

// --------------------- 4. sparse designs & arithmetic-policy checks --

fn assert_bits(a: &[f64], b: &[f64], what: &str, trial: usize) {
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert!(
            a[i].to_bits() == b[i].to_bits(),
            "{what} trial {trial} elt {i}: dense {} vs csr {}",
            a[i],
            b[i]
        );
    }
}

/// A CSR design replays the dense zero-skip accumulation order exactly, so
/// every logreg derivative oracle must agree with the dense backing TO THE
/// BIT — swapping in the sparse path can never move a gradient.
#[test]
fn logreg_oracles_dense_and_csr_agree_bitwise() {
    let mut rng = Rng::new(31);
    let ds = idiff::data::classification::make_classification(14, 5, 3, 0.3, 2.0, &mut rng);
    let csr = CsrMat::from_dense(&ds.x);
    let md = StationaryMapping::new(LogRegProblem::new(ds.x.clone(), ds.labels.clone(), 3));
    let ms = StationaryMapping::new(LogRegProblem::new(csr, ds.labels, 3));
    let (d, n) = (md.dim_x(), md.dim_theta());
    for trial in 0..5 {
        let x = rng.normal_vec(d);
        let theta = vec![rng.uniform_in(0.2, 1.5)];
        let v = rng.normal_vec(d);
        let vt = rng.normal_vec(n);
        assert_bits(&md.eval_vec(&x, &theta), &ms.eval_vec(&x, &theta), "eval", trial);
        let (mut a, mut b) = (vec![0.0; d], vec![0.0; d]);
        md.jvp_x(&x, &theta, &v, &mut a);
        ms.jvp_x(&x, &theta, &v, &mut b);
        assert_bits(&a, &b, "jvp_x", trial);
        md.vjp_x(&x, &theta, &v, &mut a);
        ms.vjp_x(&x, &theta, &v, &mut b);
        assert_bits(&a, &b, "vjp_x", trial);
        md.jvp_theta(&x, &theta, &vt, &mut a);
        ms.jvp_theta(&x, &theta, &vt, &mut b);
        assert_bits(&a, &b, "jvp_theta", trial);
        let (mut at, mut bt) = (vec![0.0; n], vec![0.0; n]);
        md.vjp_theta(&x, &theta, &v, &mut at);
        ms.vjp_theta(&x, &theta, &v, &mut bt);
        assert_bits(&at, &bt, "vjp_theta", trial);
    }
}

/// SVM products route through GEMM (dense) vs SpMM (CSR) — different
/// summation orders — so the oracles agree tightly but not bitwise.
#[test]
fn svm_oracles_dense_and_csr_agree() {
    let mut rng = Rng::new(32);
    let ds = idiff::data::classification::make_classification(12, 6, 3, 0.3, 2.0, &mut rng);
    let y = ds.one_hot();
    let md = StationaryMapping::new(MulticlassSvm::new(ds.x.clone(), y.clone()));
    let ms = StationaryMapping::new(MulticlassSvm::new(CsrMat::from_dense(&ds.x), y));
    let d = md.dim_x();
    for trial in 0..5 {
        let x = rng.normal_vec(d);
        let theta = vec![rng.uniform_in(0.6, 1.8)];
        let v = rng.normal_vec(d);
        assert!(
            close(&md.eval_vec(&x, &theta), &ms.eval_vec(&x, &theta), 1e-10),
            "eval trial {trial}"
        );
        let (mut a, mut b) = (vec![0.0; d], vec![0.0; d]);
        md.jvp_x(&x, &theta, &v, &mut a);
        ms.jvp_x(&x, &theta, &v, &mut b);
        assert!(close(&a, &b, 1e-10), "jvp_x trial {trial}");
        md.vjp_x(&x, &theta, &v, &mut a);
        ms.vjp_x(&x, &theta, &v, &mut b);
        assert!(close(&a, &b, 1e-10), "vjp_x trial {trial}");
        let (mut at, mut bt) = (vec![0.0; 1], vec![0.0; 1]);
        md.vjp_theta(&x, &theta, &v, &mut at);
        ms.vjp_theta(&x, &theta, &v, &mut bt);
        assert!(close(&at, &bt, 1e-10), "vjp_theta trial {trial}");
    }
}

/// Hypergradient of a d = 12000 CSR logreg: the whole implicit-VJP path —
/// CG on the Hessian operator, cross-products, ridge term — must stay
/// matrix-free. The densify counter catches ANY dense d×d materialisation.
#[test]
fn sparse_logreg_hypergrad_large_d_never_densifies() {
    let mut rng = Rng::new(33);
    let (m, p, k, nnz_row) = (30usize, 4000usize, 3usize, 25usize);
    let scale = 1.0 / (nnz_row as f64).sqrt();
    let mut trips = Vec::with_capacity(m * nnz_row);
    let mut labels = Vec::with_capacity(m);
    for i in 0..m {
        labels.push(i % k);
        for _ in 0..nnz_row {
            let j = (rng.uniform() * p as f64) as usize % p;
            trips.push((i, j, scale * rng.normal()));
        }
    }
    let csr = CsrMat::from_triplets(m, p, &trips);
    let prob = StationaryMapping::new(LogRegProblem::new(csr, labels, k));
    let d = p * k;
    assert_eq!(prob.dim_x(), d);
    assert!(d > FACTORIZE_DENSE_LIMIT, "test must exercise the iterative-only tier");
    let x = rng.normal_vec(d);
    let theta = vec![0.5];
    let u = rng.normal_vec(d);
    densify::reset();
    let (hg, rep) = implicit_vjp(&prob, &x, &theta, &u, &LinearSolveConfig::default());
    assert!(rep.converged, "CG on the sparse Hessian operator must converge");
    assert_eq!(hg.len(), 1);
    assert!(hg[0].is_finite());
    assert_eq!(densify::count(), 0, "d = {d} hypergrad must never build a dense d×d");
}

/// Mixed-precision implicit JVPs on the Fig. 3 ridge problem: the
/// f32-inner/f64-refined answer lands within 10× of the pure-f64 one, and
/// JVPs at approximate iterates obey Theorem 1's certified slope for BOTH
/// arithmetic policies (`diff::precision::check_bound`).
#[test]
fn mixed_precision_jvp_meets_theorem1_bound() {
    let (phi, y) = idiff::data::regression::diabetes_like(40, 6, 7);
    let rp = RidgeProblem::new(phi, y);
    let mut rng = Rng::new(34);
    let theta: Vec<f64> = (0..6).map(|_| rng.uniform_in(0.6, 1.4)).collect();
    let x_star = rp.solve_closed_form_vec(&theta);
    let root = RidgeRoot(&rp);
    let v = rng.normal_vec(6);
    let truth = rp.jacobian_closed_form(&theta).matvec(&v);
    let cfg64 = LinearSolveConfig::default();
    let cfgmx = cfg64.with_precision(SolvePrecision::MixedF32);
    let (dx64, r64) = implicit_jvp(&root, &x_star, &theta, &v, &cfg64);
    let (dxmx, rmx) = implicit_jvp(&root, &x_star, &theta, &v, &cfgmx);
    assert!(r64.converged && rmx.converged);
    let scale = vecops::norm2(&truth).max(1.0);
    let err64 = vecops::norm2(&vecops::sub(&dx64, &truth));
    let errmx = vecops::norm2(&vecops::sub(&dxmx, &truth));
    assert!(
        errmx <= 10.0 * err64.max(1e-9 * scale),
        "f64-refined mixed error {errmx} must stay within 10× of pure-f64 {err64}"
    );

    let consts = ridge_constants(&rp.x, &theta, &x_star);
    let mut dir = rng.normal_vec(6);
    let nd = vecops::norm2(&dir);
    for di in dir.iter_mut() {
        *di /= nd;
    }
    let vnorm = vecops::norm2(&v);
    let mut pairs = Vec::new();
    for &eps in &[1e-4, 1e-3, 1e-2] {
        let x_hat: Vec<f64> = x_star.iter().zip(&dir).map(|(a, b)| a + eps * b).collect();
        for cfg in [&cfg64, &cfgmx] {
            let (dx_hat, rep) = implicit_jvp(&root, &x_hat, &theta, &v, cfg);
            assert!(rep.converged);
            let jerr = vecops::norm2(&vecops::sub(&dx_hat, &truth)) / vnorm;
            pairs.push(ErrorPair { iterate_err: eps, jacobian_err: jerr });
        }
    }
    check_bound(&consts, &pairs, 0.05);
    // The Theorem-1 gate certifies the cheap policy at the solver tolerance.
    assert_eq!(select_precision(&consts, cfg64.tol, 1e-6), SolvePrecision::MixedF32);
}

#[test]
fn dense_jacobian_block_path_matches_columns_on_fixed_point_residual() {
    // The PR-1 batching property, re-checked through a fixed-point residual
    // (non-trivial ∂₁T): block dense Jacobian == column-by-column Jacobian.
    let t = ProxGradFixedPoint::new(random_quad(5, 2, 13), LassoProx { d: 5 }, 0.06);
    let res = FixedPointResidual(t);
    let theta = vec![0.3, -0.2, 0.4];
    let mut rng = Rng::new(14);
    let x = rng.normal_vec(5);
    let jb = jacobian_via_root(&res, &x, &theta);
    let jc = jacobian_via_root_columns(&res, &x, &theta);
    for i in 0..jb.data.len() {
        assert!((jb.data[i] - jc.data[i]).abs() < 1e-7, "elt {i}");
    }
}
