//! Quickstart — the paper's Figure 1 in Rust: add implicit differentiation
//! on top of a ridge-regression solver with `CustomRoot` (@custom_root).
//!
//! Run: cargo run --release --example quickstart
use idiff::diff::root::CustomRoot;
use idiff::ml::ridge::{RidgeProblem, RidgeRoot};

fn main() {
    // Load data (Φ, y) — synthetic diabetes-like design.
    let (phi, y) = idiff::data::regression::diabetes_like(442, 10, 7);
    let problem = RidgeProblem::new(phi, y);
    let p = problem.dim();

    // F(x, θ) = ∇₁f(x, θ): the optimality condition (paper Eq. 4).
    // The SOLVER is a black box — here the closed-form linear solve, exactly
    // like Figure 1's `ridge_solver`. @custom_root glues them together.
    let jac_truth = problem.jacobian_closed_form(&vec![10.0; p]);
    let solver = |_init: &[f64], theta: &[f64]| problem.solve_closed_form_vec(theta);
    let custom = CustomRoot::new(RidgeRoot(&problem), solver);

    let theta = vec![10.0; p];
    let x_star = custom.solve(&vec![0.0; p], &theta);
    println!("x*(θ=10) [first 4] = {:?}", &x_star[..4]);

    // jax.jacobian(ridge_solver, argnums=1)(init_x, 10.0) equivalent:
    let jac = custom.jacobian(&x_star, &theta);
    println!("∂x*(θ) diag [first 4] = {:?}",
        (0..4).map(|i| jac.at(i, i)).collect::<Vec<_>>());

    // Sanity: matches the closed-form Jacobian.
    let mut max_err = 0.0f64;
    for i in 0..p {
        for j in 0..p {
            max_err = max_err.max((jac.at(i, j) - jac_truth.at(i, j)).abs());
        }
    }
    println!("max |J_implicit − J_closed_form| = {max_err:.2e}");
    assert!(max_err < 1e-7);
    println!("quickstart OK");
}
