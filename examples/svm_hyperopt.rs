//! Multiclass-SVM hyper-parameter optimization (paper §4.1): bi-level
//! optimization of the regularization parameter with implicit
//! differentiation through the projected-gradient fixed point, using a BCD
//! solver — solver and fixed point independently chosen (Fig. 4c).
//!
//! Run: cargo run --release --example svm_hyperopt -- [--p 100 --outer-iters 30]
use idiff::coordinator::experiments::fig4::{self, DiffFp, Solver};
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    let p = args.get_usize("p", 100);
    let outer_iters = args.get_usize("outer-iters", 30);
    let setup = fig4::setup(args.get_usize("m", 140), p, 5, 40, args.get_u64("seed", 3));
    let mut lambda = 0.0f64;
    let mut outer = idiff::bilevel::outer::OuterGd::new(5e-3, 100);
    for it in 0..outer_iters {
        let theta = lambda.exp();
        let x_star = fig4::inner_solve(&setup, Solver::Bcd, theta, 60);
        let loss = setup.svm.outer_loss(&setup.x_val, &setup.y_val, &x_star, theta);
        let g = fig4::hypergrad_implicit(&setup, DiffFp::ProjGrad, &x_star, theta);
        let mut th = [lambda];
        outer.step(&mut th, &[g]);
        lambda = th[0];
        println!("outer {it:>3}: θ = {theta:.4}  val loss = {loss:.4}  dL/dλ = {g:+.4}");
    }
    println!("final θ = {:.4}", lambda.exp());
}
