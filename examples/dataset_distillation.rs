//! END-TO-END DRIVER (paper §4.2, Figs. 5/16): trains a multiclass logistic
//! model (~7.8k inner parameters, 784×10) through a bi-level loop on a real
//! small workload (synthetic 28×28 digit corpus), logging the outer loss
//! curve, comparing implicit vs unrolled hypergradients on runtime AND
//! quality, and dumping the distilled prototype images.
//!
//! Run: cargo run --release --example dataset_distillation -- \
//!        [--m 1000 --outer-iters 40 --inner-iters 100]
use idiff::coordinator::experiments::distill;
use idiff::util::cli::Args;

fn main() {
    let mut args = Args::parse();
    // end-to-end defaults: heavier than the bench, lighter than the paper
    if args.get("m").is_none() {
        args.options.insert("m".into(), "600".into());
    }
    if args.get("outer-iters").is_none() {
        args.options.insert("outer-iters".into(), "25".into());
    }
    if args.get("inner-iters").is_none() {
        args.options.insert("inner-iters".into(), "80".into());
    }
    let report = distill::run(&args);
    println!();
    println!("end-to-end report: {}", report.to_string_pretty());
    println!("distilled images written to results/fig5_distilled.txt");
}
