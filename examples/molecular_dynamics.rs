//! MD sensitivity analysis (paper §4.4, Figs. 6/17): relax a 2-D soft-sphere
//! packing with FIRE, then compute ∂x*(θ) w.r.t. the small-particle diameter
//! by forward-mode implicit differentiation (BiCGSTAB tangent solve).
//!
//! Run: cargo run --release --example molecular_dynamics -- [--particles 64]
use idiff::md::{random_packing, SoftSphereSystem};
use idiff::coordinator::experiments::md_sens;
use idiff::solvers::fire::FireConfig;
use idiff::util::cli::Args;
use idiff::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let n = args.get_usize("particles", 64);
    let theta = args.get_f64("theta", 0.6);
    let area = (n as f64 / 2.0) * (std::f64::consts::PI / 4.0) * (1.0 + theta * theta);
    let sys = SoftSphereSystem::new(n, (area / 1.25).sqrt());
    let mut rng = Rng::new(args.get_u64("seed", 21));
    let x0 = random_packing(n, &mut rng);
    let cfg = FireConfig { max_iter: 8000, force_tol: 1e-10, ..Default::default() };
    println!("relaxing {n} particles (box {:.2})...", sys.box_side);
    let x_star = sys.relax(&x0, theta, &cfg);
    println!("E(x*) = {:.6}", sys.energy(&x_star, theta));
    let dx = md_sens::implicit_sensitivity(&sys, &x_star, theta);
    println!("‖∂x*/∂θ‖₁ = {:.4}", idiff::linalg::vecops::norm1(&dx));
    // print the 8 most sensitive particles
    let mut norms: Vec<(usize, f64)> = (0..n)
        .map(|i| (i, (dx[2 * i].powi(2) + dx[2 * i + 1].powi(2)).sqrt()))
        .collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("most diameter-sensitive particles:");
    for (i, s) in norms.iter().take(8) {
        println!("  particle {i:>3} ({}) |∂x| = {s:.4}",
            if sys.small[*i] { "small" } else { "large" });
    }
}
