//! Serving-engine self-test client: starts the catalog server on a loopback
//! port, then walks the protocol — problem discovery, a batched hypergrad, a
//! cache-hit repeat, the legacy ridge ops, and error handling.
//!
//! Run: cargo run --release --example hypergrad_server

use idiff::coordinator::serve::{ServeConfig, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn main() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = Arc::new(Server::new(ServeConfig::default()));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }

    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let theta: Vec<String> = (0..8).map(|_| "1.0".to_string()).collect();
    let t = theta.join(",");
    let reqs = vec![
        r#"{"op": "ping"}"#.to_string(),
        r#"{"op": "problems"}"#.to_string(),
        format!(r#"{{"op": "hypergrad", "problem": "ridge", "theta": [{t}], "v": [{t}]}}"#),
        // repeat θ → served from the factorization cache ("cached": true)
        format!(r#"{{"op": "hypergrad", "problem": "ridge", "theta": [{t}], "v": [{t}]}}"#),
        r#"{"op": "jvp", "problem": "svm", "theta": [1.0], "v": [1.0]}"#.to_string(),
        r#"{"op": "solve", "problem": "lasso", "theta": [0.4]}"#.to_string(),
        format!(r#"{{"op": "ridge_jacobian", "theta": [{t}]}}"#),
        r#"{"op": "bogus"}"#.to_string(),
        r#"{"op": "stats"}"#.to_string(),
    ];
    for req in reqs {
        stream.write_all(req.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let shown = if resp.len() > 140 {
            format!("{}…", resp.chars().take(140).collect::<String>())
        } else {
            resp.clone()
        };
        println!("→ {req}\n← {shown}");
    }
    println!("hypergrad_server example OK");
}
