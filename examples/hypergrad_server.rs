//! Hypergradient request server + self-test client: the Rust binary on the
//! request path (Python was build-time only). Starts the TCP server, fires
//! a few JSON requests at it, prints the responses.
//!
//! Run: cargo run --release --example hypergrad_server
use idiff::coordinator::serve::HypergradServer;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() {
    let addr = "127.0.0.1:7979";
    std::thread::spawn(move || {
        let _ = HypergradServer::new_default().serve(addr);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let theta: Vec<String> = (0..8).map(|_| "1.0".to_string()).collect();
    let reqs = vec![
        r#"{"op": "ping"}"#.to_string(),
        format!(r#"{{"op": "ridge_hypergrad", "theta": [{t}], "v": [{t}]}}"#, t = theta.join(",")),
        format!(r#"{{"op": "ridge_jacobian", "theta": [{t}]}}"#, t = theta.join(",")),
        r#"{"op": "bogus"}"#.to_string(),
    ];
    for req in reqs {
        stream.write_all(req.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let shown = if resp.len() > 140 { format!("{}…", &resp[..140]) } else { resp.clone() };
        println!("→ {req}\n← {shown}");
    }
    println!("hypergrad_server example OK");
}
