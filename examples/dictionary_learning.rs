//! Task-driven dictionary learning (paper §4.3, Table 2) on the synthetic
//! gene-expression cohort — the full four-method comparison at small scale.
//!
//! Run: cargo run --release --example dictionary_learning -- [--p 200 --splits 3]
use idiff::coordinator::experiments::table2;
use idiff::util::cli::Args;

fn main() {
    let mut args = Args::parse();
    if args.get("p").is_none() {
        args.options.insert("p".into(), "200".into());
    }
    if args.get("splits").is_none() {
        args.options.insert("splits".into(), "3".into());
    }
    table2::run(&args);
}
