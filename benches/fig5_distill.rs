//! Regenerates paper Fig. 5/16 + the §4.2 runtime claim: dataset
//! distillation with implicit diff vs unrolling (speedup printed; distilled
//! prototypes dumped to results/fig5_distilled.txt).
use idiff::coordinator::experiments::distill;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    distill::run(&args);
}
