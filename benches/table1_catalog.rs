//! Executable form of paper Table 1: every optimality mapping instantiated
//! and its implicit Jacobian checked against finite differences.
use idiff::coordinator::experiments::table1;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    table1::run(&args);
}
