//! §Perf: XLA-oracle dispatch overhead vs the native Rust oracle on the
//! shared ridge problem (request-path cost of the AOT layer).
use idiff::coordinator::experiments::xla_parity::load_shared_problem;
use idiff::diff::spec::RootMap;
use idiff::ml::ridge::RidgeRoot;
use idiff::runtime::{artifacts_dir, XlaRidgeRoot, XlaRuntime};
use idiff::util::bench::{bench, black_box, BenchConfig};

fn main() {
    let dir = artifacts_dir();
    let rt = match XlaRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: {e:#} — run `make artifacts`");
            return;
        }
    };
    let rp = load_shared_problem(&dir).expect("ridge_data.json");
    let d = rp.dim();
    let native = RidgeRoot(&rp);
    let oracle = XlaRidgeRoot { rt: &rt, d, design: rp.x.data.clone(), targets: rp.y.clone() };
    let theta = vec![1.5; d];
    let x = rp.solve_closed_form_vec(&theta);
    let cfg = BenchConfig { warmup_iters: 3, samples: 10, reps_per_sample: 20 };
    let mut out = vec![0.0; d];
    bench("native ridge F eval", cfg, || {
        native.eval(&x, &theta, &mut out);
        black_box(out[0])
    });
    bench("xla ridge F eval (PJRT dispatch)", cfg, || {
        oracle.eval(&x, &theta, &mut out);
        black_box(out[0])
    });
    bench("native implicit jacobian", cfg, || {
        black_box(idiff::diff::root::jacobian_via_root(&native, &x, &theta))
    });
    let cfg_slow = BenchConfig { warmup_iters: 1, samples: 3, reps_per_sample: 1 };
    bench("xla implicit jacobian", cfg_slow, || {
        black_box(idiff::diff::root::jacobian_via_root(&oracle, &x, &theta))
    });
}
