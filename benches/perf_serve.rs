//! §Serving load-generator bench: throughput and latency of the catalog
//! server at k ∈ {1, 8, 32} concurrent clients over loopback TCP, in three
//! traffic shapes:
//!
//! - `shared-theta`: every client hammers ONE (problem, θ) — micro-batching
//!   coalesces concurrent solves, then the factorization cache absorbs the
//!   rest (steady state: zero iterative solves).
//! - `theta-pool`: clients draw from 8 θ's — the LRU cache's regime.
//! - `unique-theta`: every request is a fresh θ — worst case, every request
//!   pays an inner solve + block solve (batching can still coalesce nothing).
//!
//! Two extra shapes ride along:
//!
//! - `proto=json` vs `proto=binary`: identical unique-θ k=32 traffic over
//!   the JSON line protocol and the zero-copy binary frame protocol — the
//!   journaled p50/p95 ratio is the wire-format tax.
//! - `restart cold` vs `restart warm`: a fresh server paying every
//!   factorization, then a rebooted server warm-started from the first
//!   one's manifest replaying the same θ-pool traffic (expected: ZERO new
//!   factorizations).
//!
//! Journals mean/median/p95 latency and requests/s to `BENCH_serve.json`
//! (uploaded by CI next to `BENCH_linalg.json`).
//!
//! With `--cluster`, a sharding section runs too: k ∈ {64, 256} binary
//! clients drive an in-process θ-consistent-hash router in front of 1/2/4
//! `idiff serve --shard` child processes (throughput-scaling rows), plus an
//! overload cell measuring the admission reject and mode-aware degrade
//! paths on a solve-saturated engine.
//!
//! Run: cargo bench --bench perf_serve [-- --requests 80 --cluster]

use idiff::coordinator::serve::cluster::router::{Router, RouterConfig};
use idiff::coordinator::serve::wire::{self, RequestFrame};
use idiff::coordinator::serve::{ServeConfig, Server};
use idiff::util::cli::Args;
use idiff::util::json::Json;
use idiff::util::timer::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Copy, PartialEq)]
enum Traffic {
    SharedTheta,
    ThetaPool,
    UniqueTheta,
    /// 64-θ pool in a range disjoint from every other shape — wide enough
    /// that a consistent-hash ring spreads it across 4 shards.
    ClusterPool,
}

#[derive(Clone, Copy, PartialEq)]
enum Proto {
    Json,
    Binary,
}

/// `cell` salts the unique-theta stream so no bench cell replays a θ an
/// earlier cell left in the server's persistent factorization cache — the
/// "every request pays a solve" claim must actually hold.
fn theta_for(traffic: Traffic, cell: usize, client: usize, i: usize, dim: usize) -> Vec<f64> {
    let base = match traffic {
        Traffic::SharedTheta => 1.0,
        Traffic::ThetaPool => 1.0 + 0.1 * ((client * 7 + i) % 8) as f64,
        // Base 2.0 keeps the stream disjoint from the shared/pool θ's.
        Traffic::UniqueTheta => {
            2.0 + 1e-9 * (cell * 100_000_000 + client * 1_000_000 + i) as f64
        }
        Traffic::ClusterPool => 3.0 + 0.01 * ((client * 13 + i) % 64) as f64,
    };
    vec![base; dim]
}

/// A shard child process (`idiff serve --shard i/N`), killed on drop.
struct ShardProc {
    child: std::process::Child,
    addr: String,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_shard(i: usize, n: usize, workers: usize) -> ShardProc {
    let shard = format!("{i}/{n}");
    let workers = workers.to_string();
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_idiff"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--window-ms",
            "1",
            "--workers",
            &workers,
            "--shard",
            &shard,
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn shard");
    let stdout = child.stdout.take().expect("shard stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        assert!(reader.read_line(&mut line).expect("shard stdout") > 0, "shard died at boot");
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().expect("addr token").to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    ShardProc { child, addr }
}

fn run_load(
    addr: std::net::SocketAddr,
    cell: usize,
    clients: usize,
    requests_per_client: usize,
    traffic: Traffic,
    proto: Proto,
) -> (f64, Vec<f64>) {
    let t = Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || match proto {
                Proto::Json => json_client(addr, cell, c, requests_per_client, traffic),
                Proto::Binary => binary_client(addr, cell, c, requests_per_client, traffic),
            })
        })
        .collect();
    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    (t.elapsed_s(), latencies)
}

fn json_client(
    addr: std::net::SocketAddr,
    cell: usize,
    c: usize,
    requests_per_client: usize,
    traffic: Traffic,
) -> Vec<f64> {
    let mut lat = Vec::with_capacity(requests_per_client);
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    for i in 0..requests_per_client {
        let theta = theta_for(traffic, cell, c, i, 8);
        let v = vec![1.0; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&v)),
        ]);
        let rt = Timer::start();
        writer.write_all(req.to_string_compact().as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        lat.push(rt.elapsed_s());
        assert!(line.contains("\"grad\""), "bad reply: {line}");
    }
    lat
}

fn binary_client(
    addr: std::net::SocketAddr,
    cell: usize,
    c: usize,
    requests_per_client: usize,
    traffic: Traffic,
) -> Vec<f64> {
    let mut lat = Vec::with_capacity(requests_per_client);
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut frame = Vec::new();
    for i in 0..requests_per_client {
        let theta = theta_for(traffic, cell, c, i, 8);
        let v = vec![1.0; 8];
        frame.clear();
        wire::encode_request(
            &RequestFrame {
                opcode: wire::OP_VJP,
                problem: "ridge",
                theta: &theta,
                v: &v,
                ..RequestFrame::control(wire::OP_VJP)
            },
            &mut frame,
        );
        let rt = Timer::start();
        stream.write_all(&frame).unwrap();
        let reply = wire::read_reply(&mut stream).unwrap();
        lat.push(rt.elapsed_s());
        assert_eq!(reply.status, wire::STATUS_OK, "bad reply: {:?}", reply.error);
        assert_eq!(reply.data.len(), 8);
    }
    lat
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx]
}

fn main() {
    let args = Args::parse();
    let requests = args.get_usize("requests", 60);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = Arc::new(Server::new(ServeConfig {
        batch_window: Duration::from_millis(1),
        // Persistent connections hold a worker each; give the pool enough
        // slots that k=32 clients actually run concurrently (the pool is
        // still bounded — that's the point).
        workers: 40,
        ..ServeConfig::default()
    }));
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_on(listener);
        });
    }
    // Let the listener thread come up.
    std::thread::sleep(Duration::from_millis(50));

    let mut rows: Vec<Json> = Vec::new();
    let mut cell = 0usize;
    for (tname, traffic) in [
        ("shared-theta", Traffic::SharedTheta),
        ("theta-pool", Traffic::ThetaPool),
        ("unique-theta", Traffic::UniqueTheta),
    ] {
        for &k in &[1usize, 8, 32] {
            cell += 1;
            let (wall, mut lat) = run_load(addr, cell, k, requests, traffic, Proto::Json);
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = lat.len();
            let rps = n as f64 / wall;
            let mean = lat.iter().sum::<f64>() / n as f64;
            println!(
                "serve {tname:<13} k={k:<2}: {rps:>9.0} req/s  mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms",
                mean * 1e3,
                pct(&lat, 0.5) * 1e3,
                pct(&lat, 0.95) * 1e3
            );
            rows.push(Json::obj(vec![
                ("name", Json::Str(format!("serve {tname} k={k}"))),
                ("traffic", Json::Str(tname.into())),
                ("clients", Json::Num(k as f64)),
                ("requests", Json::Num(n as f64)),
                ("wall_s", Json::Num(wall)),
                ("rps", Json::Num(rps)),
                ("mean_s", Json::Num(mean)),
                ("p50_s", Json::Num(pct(&lat, 0.5))),
                ("p95_s", Json::Num(pct(&lat, 0.95))),
            ]));
        }
    }
    // ---- wire-format tax: JSON vs binary on identical unique-θ traffic ----
    // Unique θ's mean every request pays the full solve on both wires, so
    // the p50/p95 gap is down to framing + float formatting/parsing alone.
    let mut proto_p50 = [0.0f64; 2];
    let mut proto_p95 = [0.0f64; 2];
    for (slot, (pname, proto)) in
        [("json", Proto::Json), ("binary", Proto::Binary)].into_iter().enumerate()
    {
        cell += 1;
        let (wall, mut lat) = run_load(addr, cell, 32, requests, Traffic::UniqueTheta, proto);
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = lat.len();
        let rps = n as f64 / wall;
        let mean = lat.iter().sum::<f64>() / n as f64;
        proto_p50[slot] = pct(&lat, 0.5);
        proto_p95[slot] = pct(&lat, 0.95);
        println!(
            "serve unique-theta k=32 proto={pname:<6}: {rps:>9.0} req/s  mean {:.3} ms  p50 {:.3} ms  p95 {:.3} ms",
            mean * 1e3,
            proto_p50[slot] * 1e3,
            proto_p95[slot] * 1e3
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("serve unique-theta k=32 proto={pname}"))),
            ("traffic", Json::Str("unique-theta".into())),
            ("proto", Json::Str(pname.into())),
            ("clients", Json::Num(32.0)),
            ("requests", Json::Num(n as f64)),
            ("wall_s", Json::Num(wall)),
            ("rps", Json::Num(rps)),
            ("mean_s", Json::Num(mean)),
            ("p50_s", Json::Num(proto_p50[slot])),
            ("p95_s", Json::Num(proto_p95[slot])),
        ]));
    }
    // Ratio > 1.0 means binary is faster. Journaled, not asserted — shared
    // CI runners are too noisy for a hard latency gate.
    println!(
        "proto comparison: binary is {:.2}x at p50, {:.2}x at p95 vs JSON",
        proto_p50[0] / proto_p50[1],
        proto_p95[0] / proto_p95[1]
    );
    rows.push(Json::obj(vec![
        ("name", Json::Str("proto-comparison unique-theta k=32".into())),
        ("json_p50_s", Json::Num(proto_p50[0])),
        ("binary_p50_s", Json::Num(proto_p50[1])),
        ("json_p95_s", Json::Num(proto_p95[0])),
        ("binary_p95_s", Json::Num(proto_p95[1])),
        ("p50_speedup", Json::Num(proto_p50[0] / proto_p50[1])),
        ("p95_speedup", Json::Num(proto_p95[0] / proto_p95[1])),
    ]));

    // ---- cold vs warm restart: same θ-pool traffic, before/after reboot ---
    // Life 1 pays a factorization per pool θ and persists its manifest;
    // life 2 warm-starts from it and must pay ZERO new factorizations.
    let manifest =
        std::env::temp_dir().join(format!("idiff_manifest_bench_{}.json", std::process::id()));
    for phase in ["cold", "warm"] {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let phase_addr = listener.local_addr().unwrap();
        let srv = Arc::new(Server::new(ServeConfig {
            batch_window: Duration::from_millis(1),
            workers: 40,
            ..ServeConfig::default()
        }));
        if phase == "warm" {
            let warm = srv.load_manifest(&manifest).expect("load manifest");
            assert!(warm.cold_start.is_none(), "bench warm start fell back: {:?}", warm.cold_start);
        }
        {
            let srv = srv.clone();
            std::thread::spawn(move || {
                let _ = srv.serve_on(listener);
            });
        }
        std::thread::sleep(Duration::from_millis(50));
        let (wall, mut lat) = run_load(phase_addr, 0, 8, requests, Traffic::ThetaPool, Proto::Binary);
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = lat.len();
        let facts = srv.stats.factorizations.load(Ordering::Relaxed);
        println!(
            "serve restart {phase:<4}: {:>9.0} req/s  p50 {:.3} ms  p95 {:.3} ms  factorizations {facts}",
            n as f64 / wall,
            pct(&lat, 0.5) * 1e3,
            pct(&lat, 0.95) * 1e3
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("serve restart {phase}"))),
            ("phase", Json::Str(phase.into())),
            ("clients", Json::Num(8.0)),
            ("requests", Json::Num(n as f64)),
            ("wall_s", Json::Num(wall)),
            ("rps", Json::Num(n as f64 / wall)),
            ("p50_s", Json::Num(pct(&lat, 0.5))),
            ("p95_s", Json::Num(pct(&lat, 0.95))),
            ("factorizations", Json::Num(facts as f64)),
        ]));
        if phase == "cold" {
            assert!(facts > 0, "cold phase should have factorized the θ pool");
            srv.save_manifest(&manifest).expect("save manifest");
        } else {
            assert_eq!(facts, 0, "warm restart must not re-factorize pool θ's");
        }
    }
    let _ = std::fs::remove_file(&manifest);

    // ---- cluster scaling: k clients × {1,2,4} shard processes ------------
    // Opt-in (--cluster): spawns child processes, so the quick default run
    // stays self-contained. Clients speak the binary wire to an in-process
    // router fronting `idiff serve --shard i/N` children; steady-state
    // traffic is the 64-θ ClusterPool, so rows measure how the ring spreads
    // the cache (and the request load) across shards.
    if args.flag("cluster") {
        let creq = args.get_usize("cluster-requests", 10);
        for &nshards in &[1usize, 2, 4] {
            let shards: Vec<ShardProc> =
                (0..nshards).map(|i| spawn_shard(i, nshards, 300)).collect();
            let router = Arc::new(Router::new(RouterConfig {
                shards: shards.iter().map(|s| s.addr.clone()).collect(),
                workers: 300,
                ..RouterConfig::default()
            }));
            let rlistener = TcpListener::bind("127.0.0.1:0").expect("bind router");
            let raddr = rlistener.local_addr().unwrap();
            {
                let router = router.clone();
                std::thread::spawn(move || {
                    let _ = router.serve_on(rlistener);
                });
            }
            std::thread::sleep(Duration::from_millis(50));
            for &k in &[64usize, 256] {
                cell += 1;
                let (wall, mut lat) =
                    run_load(raddr, cell, k, creq, Traffic::ClusterPool, Proto::Binary);
                lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = lat.len();
                let rps = n as f64 / wall;
                println!(
                    "serve cluster shards={nshards} k={k:<3}: {rps:>9.0} req/s  p50 {:.3} ms  p95 {:.3} ms",
                    pct(&lat, 0.5) * 1e3,
                    pct(&lat, 0.95) * 1e3
                );
                rows.push(Json::obj(vec![
                    ("name", Json::Str(format!("serve cluster shards={nshards} k={k}"))),
                    ("shards", Json::Num(nshards as f64)),
                    ("clients", Json::Num(k as f64)),
                    ("requests", Json::Num(n as f64)),
                    ("wall_s", Json::Num(wall)),
                    ("rps", Json::Num(rps)),
                    ("p50_s", Json::Num(pct(&lat, 0.5))),
                    ("p95_s", Json::Num(pct(&lat, 0.95))),
                    (
                        "forwarded",
                        Json::Num(router.stats.forwarded.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "failovers",
                        Json::Num(router.stats.failovers.load(Ordering::Relaxed) as f64),
                    ),
                ]));
            }
        }

        // ---- overload reject + mode-aware degrade, solve lane saturated --
        // In-process engine with ONE solve slot deliberately held: implicit
        // requests shed with the canonical reject; auto requests with a
        // cached ρ are served solve-free (degraded). Both paths journaled.
        let srv = Server::new(ServeConfig {
            batch_window: Duration::from_millis(0),
            max_solve_inflight: 1,
            ..ServeConfig::default()
        });
        let theta_auto = vec![0.9; 8];
        let auto_line = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta_auto)),
            ("v", Json::arr_f64(&vec![1.0; 8])),
            ("mode", Json::Str("auto".into())),
        ])
        .to_string_compact();
        let r = srv.handle(&auto_line);
        assert!(r.get("error").is_none(), "warm-up failed: {}", r.to_string_compact());
        let hold = srv.admission().solve_slot().expect("claim the only solve slot");
        let m = 200usize;
        let t = Timer::start();
        for i in 0..m {
            let theta = vec![4.0 + 1e-6 * i as f64; 8];
            let line = Json::obj(vec![
                ("op", Json::Str("hypergrad".into())),
                ("problem", Json::Str("ridge".into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(&vec![1.0; 8])),
            ])
            .to_string_compact();
            let r = srv.handle(&line);
            assert_eq!(r.to_string_compact(), r#"{"error":"overloaded"}"#);
        }
        let reject_wall = t.elapsed_s();
        let t = Timer::start();
        for _ in 0..m {
            let r = srv.handle(&auto_line);
            assert_eq!(r.get("degraded"), Some(&Json::Bool(true)), "expected degraded reply");
        }
        let degrade_wall = t.elapsed_s();
        drop(hold);
        assert_eq!(srv.admission().rejected(), m as u64);
        assert_eq!(srv.admission().degraded_one_step(), m as u64);
        println!(
            "serve cluster overload: reject {:>9.0} req/s  degrade-to-one-step {:>9.0} req/s",
            m as f64 / reject_wall,
            m as f64 / degrade_wall
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str("serve cluster overload-degrade".into())),
            ("rejected", Json::Num(m as f64)),
            ("degraded_one_step", Json::Num(m as f64)),
            ("reject_rps", Json::Num(m as f64 / reject_wall)),
            ("degrade_rps", Json::Num(m as f64 / degrade_wall)),
        ]));
    }

    // Final engine counters: how much the batcher and cache absorbed.
    let stats = server.handle(r#"{"op":"stats"}"#);
    println!("engine stats: {}", stats.to_string_compact());
    rows.push(Json::obj(vec![
        ("name", Json::Str("engine-stats".into())),
        ("stats", stats),
    ]));
    let journal = Json::obj(vec![("results", Json::Arr(rows))]);
    match std::fs::write("BENCH_serve.json", journal.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote BENCH_serve.json"),
        Err(e) => eprintln!("[bench] FAILED to write BENCH_serve.json: {e}"),
    }
}
