//! Regenerates paper Fig. 15: SVM Jacobian error vs solution error, through
//! the batched implicit-diff engine. Also times the multi-cotangent block
//! solve against the column-by-column VJP loop on the largest problem
//! (`--cotangents k`, default 8) — the wall-time row in EXPERIMENTS.md
//! §Perf — and checks the two paths agree.
use idiff::coordinator::experiments::fig15;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig15::run(&args);
}
