//! Regenerates paper Fig. 15: SVM Jacobian error vs solution error.
use idiff::coordinator::experiments::fig15;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig15::run(&args);
}
