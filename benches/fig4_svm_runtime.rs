//! Regenerates paper Fig. 4(a–c): CPU runtime of implicit diff vs unrolling
//! for multiclass-SVM hyper-parameter optimization across problem sizes.
//! `--solver md|pg|bcd` picks the panel; defaults run all three at CI scale.
use idiff::coordinator::experiments::fig4;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    match args.get_or("solver", "all") {
        "md" => {
            fig4::run_md(&args);
        }
        "pg" => {
            fig4::run_pg(&args);
        }
        "bcd" => {
            fig4::run_bcd(&args);
        }
        _ => {
            println!("--- Fig. 4(a): mirror descent ---");
            fig4::run_md(&args);
            println!("--- Fig. 4(b): proximal gradient ---");
            fig4::run_pg(&args);
            println!("--- Fig. 4(c): block coordinate descent ---");
            fig4::run_bcd(&args);
        }
    }
}
