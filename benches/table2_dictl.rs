//! Regenerates paper Table 2: survival-prediction AUC for L1/L2 logreg,
//! unsupervised DictL + logreg, and task-driven DictL (bilevel implicit).
use idiff::coordinator::experiments::table2;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    table2::run(&args);
}
