//! Regenerates paper Fig. 17: MD position-sensitivity norms across seeds —
//! implicit (BiCGSTAB) converges, unrolling through FIRE diverges.
use idiff::coordinator::experiments::md_sens;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    md_sens::run(&args);
}
