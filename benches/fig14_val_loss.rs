//! Regenerates paper Fig. 14: final validation loss parity across methods.
use idiff::coordinator::experiments::fig4;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig4::run_val_loss(&args);
}
