//! Regenerates paper Fig. 3 (Jacobian estimate error vs iterate error).
//! Rows/series printed match the paper's curves: implicit, unrolled, bound.
use idiff::coordinator::experiments::fig3;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig3::run(&args);
}
