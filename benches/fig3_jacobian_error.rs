//! Regenerates paper Fig. 3 (Jacobian estimate error vs iterate error) as a
//! three-way mode comparison: implicit, unrolled, one-step, plus the
//! Theorem-1 bound curve. Also journals the per-mode accuracy/latency
//! summary at the converged solution to `BENCH_modes.json`
//! (EXPERIMENTS.md §Modes).
use idiff::coordinator::experiments::fig3;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig3::run(&args);
}
