//! Regenerates paper Fig. 13's OOM story: reverse-mode unrolling memory vs
//! the 16 GiB accelerator budget, across problem sizes (paper scale).
use idiff::coordinator::experiments::fig4;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    fig4::run_memory(&args);
}
