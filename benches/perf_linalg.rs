//! §Perf micro-benchmarks for the L3 hot paths: gemm, gemv, CG iterations,
//! simplex projection, softmax rows. Used to drive the optimization pass
//! recorded in EXPERIMENTS.md §Perf.
use idiff::linalg::{op::DenseOp, Mat};
use idiff::util::bench::{bench, black_box, BenchConfig};
use idiff::util::cli::Args;
use idiff::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let n = args.get_usize("n", 256);
    let mut rng = Rng::new(1);
    let a = Mat::randn(n, n, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let spd = a.gram().plus_diag(1.0);
    let v = rng.normal_vec(n);
    let cfg = BenchConfig { warmup_iters: 2, samples: 8, reps_per_sample: 1 };

    let flops = 2.0 * (n as f64).powi(3);
    let m = bench(&format!("gemm {n}x{n}x{n}"), cfg, || black_box(a.matmul(&b)));
    println!("  → {:.2} GFLOP/s", flops / m.mean_s() / 1e9);
    bench(&format!("gemm-t {n}x{n}x{n} (AᵀB)"), cfg, || black_box(a.t_matmul(&b)));
    bench(&format!("gram {n}x{n}"), cfg, || black_box(a.gram()));
    let cfg_fast = BenchConfig { warmup_iters: 2, samples: 8, reps_per_sample: 50 };
    bench(&format!("gemv {n}x{n}"), cfg_fast, || black_box(a.matvec(&v)));
    bench(&format!("gemv-t {n}x{n}"), cfg_fast, || black_box(a.matvec_t(&v)));
    bench(&format!("cg solve {n} (tol 1e-10)"), cfg, || {
        let mut x = vec![0.0; n];
        idiff::linalg::cg::cg(&DenseOp::symmetric(&spd), &v, &mut x, 1e-10, 4 * n);
        black_box(x)
    });
    let y = rng.normal_vec(4096);
    bench("simplex projection d=4096", cfg_fast, || {
        let mut out = vec![0.0; 4096];
        idiff::proj::simplex::project_simplex(&y, &mut out);
        black_box(out)
    });
    let rows = rng.normal_vec(700 * 5);
    bench("softmax rows 700x5", cfg_fast, || {
        let mut out = vec![0.0; 700 * 5];
        idiff::proj::simplex::softmax_rows(&rows, 5, &mut out);
        black_box(out)
    });
}
