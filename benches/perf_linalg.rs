//! §Perf micro-benchmarks for the L3 hot paths: packed parallel gemm (with
//! GFLOP/s), gemv, CG, block-CG vs column-by-column multi-RHS solves,
//! simplex projection, softmax rows. Results are printed AND journaled to
//! `BENCH_linalg.json` so the perf trajectory is tracked across PRs — the
//! numbers land in EXPERIMENTS.md §Perf.
use idiff::diff::root::implicit_vjp;
use idiff::linalg::op::densify;
use idiff::linalg::solve::LinearSolveConfig;
use idiff::linalg::{cg, gemm_config, op::DenseOp, simd_tier, CsrMat, GemmConfig, Mat};
use idiff::mappings::stationary::StationaryMapping;
use idiff::ml::logreg::LogRegProblem;
use idiff::util::bench::{bench, black_box, BenchConfig, BenchJournal};
use idiff::util::cli::Args;
use idiff::util::json::Json;
use idiff::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let n = args.get_usize("n", 256);
    let k = args.get_usize("k", 8);
    let mut rng = Rng::new(1);
    let a = Mat::randn(n, n, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let spd = a.gram().plus_diag(1.0);
    let v = rng.normal_vec(n);
    let cfg = BenchConfig { warmup_iters: 2, samples: 8, reps_per_sample: 1 };
    let mut journal = BenchJournal::new();

    println!("cpu: simd tier {}, autotuned gemm {}", simd_tier(), gemm_config());
    journal.note(Json::obj(vec![
        ("name", Json::Str("cpu_features".into())),
        ("simd_tier", Json::Str(simd_tier().to_string())),
        ("gemm_config", Json::Str(gemm_config().to_string())),
    ]));

    let flops3 = 2.0 * (n as f64).powi(3);
    let m = bench(&format!("gemm {n}x{n}x{n}"), cfg, || black_box(a.matmul(&b)));
    println!("  → {:.2} GFLOP/s", flops3 / m.mean_s() / 1e9);
    journal.record(&m, Some(flops3));
    let m = bench(&format!("gemm-t {n}x{n}x{n} (AᵀB)"), cfg, || black_box(a.t_matmul(&b)));
    println!("  → {:.2} GFLOP/s", flops3 / m.mean_s() / 1e9);
    journal.record(&m, Some(flops3));
    let m = bench(&format!("gram {n}x{n}"), cfg, || black_box(a.gram()));
    journal.record(&m, Some(flops3));

    // SIMD microkernel vs the portable scalar kernel, same blocking machinery.
    let m_scalar = bench(&format!("gemm scalar-kernel {n}x{n}x{n}"), cfg, || {
        black_box(a.matmul_cfg(&b, GemmConfig::scalar()))
    });
    println!("  → {:.2} GFLOP/s", flops3 / m_scalar.mean_s() / 1e9);
    journal.record(&m_scalar, Some(flops3));
    let m_simd = bench(&format!("gemm autotuned {n}x{n}x{n} [{}]", gemm_config()), cfg, || {
        black_box(a.matmul_cfg(&b, gemm_config()))
    });
    println!("  → {:.2} GFLOP/s", flops3 / m_simd.mean_s() / 1e9);
    journal.record(&m_simd, Some(flops3));
    let kernel_speedup = m_scalar.mean_s() / m_simd.mean_s().max(1e-30);
    println!("  → autotuned kernel speedup over scalar: {kernel_speedup:.2}x");
    journal.note(Json::obj(vec![
        ("name", Json::Str(format!("simd_vs_scalar_gemm n={n}"))),
        ("scalar_s", Json::Num(m_scalar.mean_s())),
        ("simd_s", Json::Num(m_simd.mean_s())),
        ("speedup", Json::Num(kernel_speedup)),
    ]));

    let cfg_fast = BenchConfig { warmup_iters: 2, samples: 8, reps_per_sample: 50 };
    let flops2 = 2.0 * (n as f64).powi(2);
    let m = bench(&format!("gemv {n}x{n}"), cfg_fast, || black_box(a.matvec(&v)));
    println!("  → {:.2} GFLOP/s", flops2 / m.mean_s() / 1e9);
    journal.record(&m, Some(flops2));
    let m = bench(&format!("gemv-t {n}x{n}"), cfg_fast, || black_box(a.matvec_t(&v)));
    journal.record(&m, Some(flops2));

    let m = bench(&format!("cg solve {n} (tol 1e-10)"), cfg, || {
        let mut x = vec![0.0; n];
        cg::cg(&DenseOp::symmetric(&spd), &v, &mut x, 1e-10, 4 * n);
        black_box(x)
    });
    journal.record(&m, None);

    // Multi-RHS: k independent CG solves vs ONE block-CG sharing a single
    // (GEMM) operator application per iteration.
    let bmat = Mat::randn(n, k, &mut rng);
    let op = DenseOp::symmetric(&spd);
    let m_cols = bench(&format!("cg column loop {n}, k={k}"), cfg, || {
        let mut xs = Mat::zeros(n, k);
        let mut bc = vec![0.0; n];
        let mut xc = vec![0.0; n];
        for j in 0..k {
            bmat.col_into(j, &mut bc);
            xc.iter_mut().for_each(|x| *x = 0.0);
            cg::cg(&op, &bc, &mut xc, 1e-10, 4 * n);
            xs.set_col(j, &xc);
        }
        black_box(xs)
    });
    journal.record(&m_cols, None);
    let m_block = bench(&format!("block-cg {n}, k={k}"), cfg, || {
        let mut xs = Mat::zeros(n, k);
        cg::block_cg(&op, &bmat, &mut xs, 1e-10, 4 * n);
        black_box(xs)
    });
    journal.record(&m_block, None);
    let speedup = m_cols.mean_s() / m_block.mean_s().max(1e-30);
    println!("  → block-CG speedup over column loop: {speedup:.2}x");
    journal.note(Json::obj(vec![
        ("name", Json::Str(format!("block_vs_column_cg n={n} k={k}"))),
        ("column_s", Json::Num(m_cols.mean_s())),
        ("block_s", Json::Num(m_block.mean_s())),
        ("speedup", Json::Num(speedup)),
    ]));

    let y = rng.normal_vec(4096);
    let m = bench("simplex projection d=4096", cfg_fast, || {
        let mut out = vec![0.0; 4096];
        idiff::proj::simplex::project_simplex(&y, &mut out);
        black_box(out)
    });
    journal.record(&m, None);
    let rows = rng.normal_vec(700 * 5);
    let m = bench("softmax rows 700x5", cfg_fast, || {
        let mut out = vec![0.0; 700 * 5];
        idiff::proj::simplex::softmax_rows(&rows, 5, &mut out);
        black_box(out)
    });
    journal.record(&m, None);

    // Sparse CSR design vs the same logreg with a dense design: one
    // hypergradient (implicit VJP, matrix-free CG on the Hessian operator)
    // at d = 12000 — past FACTORIZE_DENSE_LIMIT, so both sides are
    // iterative and the densify counter proves no d×d was materialised.
    let (sm, sp, sk, nnz_row) = (30usize, 4000usize, 3usize, 25usize);
    let scale = 1.0 / (nnz_row as f64).sqrt();
    let mut trips = Vec::with_capacity(sm * nnz_row);
    let mut labels = Vec::with_capacity(sm);
    for i in 0..sm {
        labels.push(i % sk);
        for _ in 0..nnz_row {
            let j = (rng.uniform() * sp as f64) as usize % sp;
            trips.push((i, j, scale * rng.normal()));
        }
    }
    let csr = CsrMat::from_triplets(sm, sp, &trips);
    let dense = csr.to_dense_mat();
    let sparse_prob = StationaryMapping::new(LogRegProblem::new(csr, labels.clone(), sk));
    let dense_prob = StationaryMapping::new(LogRegProblem::new(dense, labels, sk));
    let d = sp * sk;
    let x = rng.normal_vec(d);
    let u = rng.normal_vec(d);
    let theta = [0.5];
    let scfg = LinearSolveConfig::default();
    densify::reset();
    let m_densed = bench(&format!("logreg hypergrad dense design d={d}"), cfg, || {
        black_box(implicit_vjp(&dense_prob, &x, &theta, &u, &scfg))
    });
    journal.record(&m_densed, None);
    let m_sparse = bench(&format!("logreg hypergrad csr design d={d}"), cfg, || {
        black_box(implicit_vjp(&sparse_prob, &x, &theta, &u, &scfg))
    });
    journal.record(&m_sparse, None);
    assert_eq!(densify::count(), 0, "large-d hypergrad must stay matrix-free");
    let sparse_speedup = m_densed.mean_s() / m_sparse.mean_s().max(1e-30);
    println!("  → CSR-design hypergrad speedup over dense design: {sparse_speedup:.2}x (densified: 0)");
    journal.note(Json::obj(vec![
        ("name", Json::Str(format!("sparse_vs_dense_logreg_hypergrad d={d}"))),
        ("dense_s", Json::Num(m_densed.mean_s())),
        ("sparse_s", Json::Num(m_sparse.mean_s())),
        ("speedup", Json::Num(sparse_speedup)),
        ("densified", Json::Num(densify::count() as f64)),
    ]));

    journal.write("BENCH_linalg.json");
}
