"""AOT pipeline: lower every L2 oracle to HLO **text** + write the manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that the runtime's xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md). Lowering goes stablehlo →
XlaComputation(return_tuple=True) → as_hlo_text, and the Rust side unwraps
the 1-tuple.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    # keep_unused: oracle signatures stay uniform even when an argument does
    # not affect the output (e.g. x in ∂₁F·v for a linear F) — the Rust side
    # always passes the full argument list.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"oracles": []}
    for name, (fn, args) in model.oracle_specs().items():
        text = to_hlo_text(fn, args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        n_out = len(fn(*args))
        manifest["oracles"].append(
            {
                "name": name,
                "file": fname,
                "in_shapes": [list(a.shape) for a in args],
                "n_outputs": n_out,
            }
        )
        print(f"[aot] {name}: {len(text)} chars, in_shapes={[list(a.shape) for a in args]}")
    model.export_ridge_data(out_dir)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest['oracles'])} oracles to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
