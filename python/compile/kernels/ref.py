"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
(pytest asserts allclose between each kernel and its ref across shapes)."""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def soft_threshold_ref(y, lam):
    return jnp.sign(y) * jnp.maximum(jnp.abs(y) - lam[0], 0.0)


def row_softmax_ref(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def ridge_f_ref(x_vec, theta, design, targets):
    """F(x, θ) = Φᵀ(Φx − y) + θ⊙x — the Fig. 1 optimality mapping."""
    r = design @ x_vec - targets
    return design.T @ r + theta * x_vec
