"""L1 Pallas kernels: fused elementwise operators.

- ``soft_threshold``: the lasso prox ST(y, λ) = sign(y)·max(|y| − λ, 0),
  fused in one VMEM pass (paper Appendix C.2).
- ``row_softmax``: the KL/Bregman projection onto the simplex, one row block
  per grid step (paper Appendix C.1).

Lane-aligned (·, 128)-style blocks on TPU; interpret=True on this CPU image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _soft_threshold_kernel(y_ref, lam_ref, o_ref):
    y = y_ref[...]
    lam = lam_ref[0]
    o_ref[...] = jnp.sign(y) * jnp.maximum(jnp.abs(y) - lam, 0.0)


@jax.jit
def soft_threshold(y, lam):
    """ST(y, λ) for a flat f32 vector y and scalar λ (shape (1,))."""
    (n,) = y.shape
    block = n
    # single block: the operator is memory-bound; one fused pass
    return pl.pallas_call(
        _soft_threshold_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), y.dtype),
        interpret=True,
    )(y, lam)


def _row_softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def row_softmax(x, block_rows: int = 8):
    """Row-wise softmax of an (m, k) matrix, one row-block per grid step."""
    m, k = x.shape
    b = min(block_rows, m)
    while m % b != 0:
        b -= 1
    return pl.pallas_call(
        _row_softmax_kernel,
        grid=(m // b,),
        in_specs=[pl.BlockSpec((b, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=True,
    )(x)
