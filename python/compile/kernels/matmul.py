"""L1 Pallas kernel: VMEM-tiled matmul (the Gram / Hessian-vector hot spot).

TPU mapping (DESIGN.md §Hardware-Adaptation): tiles are sized for VMEM and
shaped for the 128×128 MXU; on this CPU image the kernel runs under
``interpret=True`` (real-TPU lowering emits a Mosaic custom call the CPU
PJRT client cannot execute). Correctness is pinned to ``ref.matmul_ref`` by
pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += X[i,k] @ Y[k,j].

    The k axis revisits the same output block, so o_ref doubles as the f32
    accumulator: zeroed at k = 0, accumulated into afterwards.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``target`` (keeps the grid
    exact without padding logic)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """Tiled ``x @ y`` via Pallas (interpret mode on CPU).

    Block sizes default to the MXU-native 128; for small operands the
    blocks shrink to exact divisors so the grid tiles the problem.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul shape mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def matvec(a, v):
    """A @ v through the tiled kernel (v lifted to a column)."""
    return matmul(a, v[:, None])[:, 0]
