"""L2 — the JAX compute graphs AOT-compiled for the Rust runtime.

Ridge regression (the paper's Fig. 1 running example) on a fixed synthetic
design matrix: the optimality mapping F(x, θ) = Φᵀ(Φx − y) + θ⊙x and its two
JVP oracles, with every matrix product routed through the L1 Pallas matmul
kernel so the whole three-layer stack (Pallas → JAX → HLO → Rust PJRT) is
exercised on the Rust request path.

The design matrix is generated HERE (numpy PRNG) and exported alongside the
HLO artifacts (``ridge_data.json``) so the Rust side constructs the *same*
problem for its native-vs-XLA parity check — no cross-language PRNG
dependency.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import elementwise, matmul

# Fixed problem size for the AOT artifacts (shapes are static in HLO).
RIDGE_M = 64
RIDGE_D = 16
RIDGE_SEED = 12345


def make_ridge_data():
    """Standardized correlated design + targets (diabetes-like, f32)."""
    rng = np.random.default_rng(RIDGE_SEED)
    latent = rng.standard_normal((RIDGE_M, RIDGE_D // 2))
    mixing = rng.standard_normal((RIDGE_D // 2, RIDGE_D))
    x = latent @ mixing + 0.5 * rng.standard_normal((RIDGE_M, RIDGE_D))
    x -= x.mean(axis=0, keepdims=True)
    x /= np.linalg.norm(x, axis=0, keepdims=True)
    w = rng.standard_normal(RIDGE_D)
    y = x @ w + 0.05 * rng.standard_normal(RIDGE_M)
    return x.astype(np.float32), y.astype(np.float32)


DESIGN, TARGETS = make_ridge_data()
_DESIGN_J = jnp.asarray(DESIGN)
_TARGETS_J = jnp.asarray(TARGETS)


# NOTE: the design matrix and targets are passed as runtime ARGUMENTS, not
# baked in as constants — ``as_hlo_text()`` elides large constants
# (``constant({...})``), which would zero them out after the text round-trip.

def _mm(a, v):
    """a @ v through the Pallas matmul kernel (v a vector)."""
    return matmul.matmul(a, v[:, None])[:, 0]


def ridge_f(x, theta, design, targets):
    """F(x, θ) = Φᵀ(Φx − y) + θ⊙x."""
    r = _mm(design, x) - targets
    return (_mm(design.T, r) + theta * x,)


def ridge_f_jvp_x(x, theta, v, design, targets):
    """∂₁F·v = Φᵀ(Φv) + θ⊙v (x, targets unused: F is linear in x; kept for
    a uniform oracle signature)."""
    del x, targets
    return (_mm(design.T, _mm(design, v)) + theta * v,)


def ridge_f_jvp_theta(x, theta, v):
    """∂₂F·v = v⊙x."""
    del theta
    return (v * x,)


def lasso_prox(y, lam):
    """The L1 soft-threshold kernel as a standalone oracle."""
    return (elementwise.soft_threshold(y, lam),)


def simplex_kl_projection(scores):
    """Row-softmax (KL projection onto simplex rows) as an oracle."""
    return (elementwise.row_softmax(scores),)


def oracle_specs():
    """Manifest of everything aot.py lowers: name → (fn, example args)."""
    d = RIDGE_D
    xv = jnp.zeros((d,), jnp.float32)
    dm = jnp.zeros((RIDGE_M, d), jnp.float32)
    tv = jnp.zeros((RIDGE_M,), jnp.float32)
    return {
        "ridge_f": (ridge_f, (xv, xv, dm, tv)),
        "ridge_f_jvp_x": (ridge_f_jvp_x, (xv, xv, xv, dm, tv)),
        "ridge_f_jvp_theta": (ridge_f_jvp_theta, (xv, xv, xv)),
        "lasso_prox": (lasso_prox, (jnp.zeros((256,), jnp.float32), jnp.zeros((1,), jnp.float32))),
        "simplex_kl_projection": (simplex_kl_projection, (jnp.zeros((32, 8), jnp.float32),)),
    }


def export_ridge_data(out_dir: str):
    """Write the shared problem data for the Rust parity check."""
    payload = {
        "m": RIDGE_M,
        "d": RIDGE_D,
        "x": [float(v) for v in DESIGN.reshape(-1)],
        "y": [float(v) for v in TARGETS],
    }
    with open(os.path.join(out_dir, "ridge_data.json"), "w") as f:
        json.dump(payload, f)


def ridge_f_reference(x, theta):
    """Pure-jnp reference for tests (no Pallas)."""
    r = _DESIGN_J @ x - _TARGETS_J
    return _DESIGN_J.T @ r + theta * x
