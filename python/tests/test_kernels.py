"""L1 kernel correctness: Pallas vs pure-jnp refs, hypothesis-swept shapes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, matmul, ref

RNG = np.random.default_rng(0)


def rand(shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 40),
        k=st.integers(1, 40),
        n=st.integers(1, 40),
    )
    def test_matches_ref_random_shapes(self, m, k, n):
        x = rand((m, k))
        y = rand((k, n))
        got = matmul.matmul(x, y)
        want = ref.matmul_ref(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize(
        "shape",
        [(128, 128, 128), (256, 64, 128), (64, 256, 32), (1, 128, 1), (200, 200, 200)],
    )
    def test_tiled_shapes(self, shape):
        m, k, n = shape
        x = rand((m, k))
        y = rand((k, n))
        np.testing.assert_allclose(
            matmul.matmul(x, y), ref.matmul_ref(x, y), rtol=2e-5, atol=2e-5
        )

    def test_explicit_small_blocks(self):
        x = rand((64, 64))
        y = rand((64, 64))
        got = matmul.matmul(x, y, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-5, atol=2e-5)

    def test_matvec(self):
        a = rand((48, 32))
        v = rand((32,))
        np.testing.assert_allclose(matmul.matvec(a, v), a @ v, rtol=1e-5, atol=1e-5)

    def test_identity(self):
        x = rand((32, 32))
        eye = np.eye(32, dtype=np.float32)
        np.testing.assert_allclose(matmul.matmul(x, eye), x, rtol=1e-6, atol=1e-6)


class TestSoftThreshold:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 512), lam=st.floats(0.0, 3.0))
    def test_matches_ref(self, n, lam):
        y = rand((n,), scale=2.0)
        lam_arr = np.array([lam], dtype=np.float32)
        got = elementwise.soft_threshold(y, lam_arr)
        want = ref.soft_threshold_ref(y, lam_arr)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_kills_small_entries(self):
        y = np.array([0.5, -0.5, 2.0, -2.0], dtype=np.float32)
        lam = np.array([1.0], dtype=np.float32)
        got = np.asarray(elementwise.soft_threshold(y, lam))
        np.testing.assert_allclose(got, [0.0, 0.0, 1.0, -1.0], atol=1e-7)

    def test_nonexpansive(self):
        a = rand((128,))
        b = rand((128,))
        lam = np.array([0.7], dtype=np.float32)
        pa = np.asarray(elementwise.soft_threshold(a, lam))
        pb = np.asarray(elementwise.soft_threshold(b, lam))
        assert np.linalg.norm(pa - pb) <= np.linalg.norm(a - b) + 1e-6


class TestRowSoftmax:
    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 40), k=st.integers(1, 16))
    def test_matches_ref(self, m, k):
        x = rand((m, k), scale=3.0)
        got = elementwise.row_softmax(x)
        want = ref.row_softmax_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_rows_sum_to_one(self):
        x = rand((16, 8), scale=5.0)
        got = np.asarray(elementwise.row_softmax(x))
        np.testing.assert_allclose(got.sum(axis=1), np.ones(16), rtol=1e-5)
        assert (got > 0).all()

    def test_shift_invariance(self):
        x = rand((4, 6))
        a = np.asarray(elementwise.row_softmax(x))
        b = np.asarray(elementwise.row_softmax(x + 100.0))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_dtype_preserved(self):
        x = rand((8, 4))
        assert elementwise.row_softmax(x).dtype == jnp.float32
