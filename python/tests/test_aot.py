"""AOT pipeline: HLO text artifacts are produced, parseable-looking, and the
manifest + data export are consistent."""

import json
import os

from compile import aot, model


def test_build_produces_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out)
    names = {o["name"] for o in manifest["oracles"]}
    assert {"ridge_f", "ridge_f_jvp_x", "ridge_f_jvp_theta"} <= names
    for o in manifest["oracles"]:
        path = os.path.join(out, o["file"])
        assert os.path.exists(path), o["file"]
        text = open(path).read()
        # HLO text essentials: a module header and an ENTRY computation.
        assert text.startswith("HloModule"), o["name"]
        assert "ENTRY" in text, o["name"]
        # return_tuple=True → root is a tuple
        assert "tuple" in text, o["name"]

    # manifest round-trips through json and matches what's on disk
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == manifest

    # shared ridge data exported for the Rust parity check
    data = json.load(open(os.path.join(out, "ridge_data.json")))
    assert data["m"] == model.RIDGE_M
    assert data["d"] == model.RIDGE_D
    assert len(data["x"]) == model.RIDGE_M * model.RIDGE_D
    assert len(data["y"]) == model.RIDGE_M


def test_hlo_contains_pallas_lowered_dot(tmp_path):
    # interpret=True lowers the Pallas matmul into plain HLO ops that the
    # CPU PJRT client can execute — there must be a dot/convolution and no
    # mosaic custom-call.
    out = str(tmp_path / "a")
    aot.build(out)
    text = open(os.path.join(out, "ridge_f.hlo.txt")).read()
    assert "custom-call" not in text or "Mosaic" not in text
    assert "dot(" in text or "dot." in text or "dot " in text
