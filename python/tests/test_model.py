"""L2 model oracles: Pallas-backed ridge F vs pure-jnp reference, gradient
consistency, and shape checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def rand_vec(seed, n=model.RIDGE_D):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


class TestRidgeOracles:
    def test_f_matches_reference(self):
        x = jnp.asarray(rand_vec(1))
        theta = jnp.abs(jnp.asarray(rand_vec(2)))
        (got,) = model.ridge_f(x, theta, model._DESIGN_J, model._TARGETS_J)
        want = model.ridge_f_reference(x, theta)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_f_is_gradient_of_objective(self):
        # F must equal ∇_x [½‖Φx−y‖² + ½Σθᵢxᵢ²]
        def obj(x, theta):
            r = jnp.asarray(model.DESIGN) @ x - jnp.asarray(model.TARGETS)
            return 0.5 * jnp.sum(r**2) + 0.5 * jnp.sum(theta * x * x)

        x = jnp.asarray(rand_vec(3))
        theta = jnp.abs(jnp.asarray(rand_vec(4)))
        g = jax.grad(obj, argnums=0)(x, theta)
        (f,) = model.ridge_f(x, theta, model._DESIGN_J, model._TARGETS_J)
        np.testing.assert_allclose(f, g, rtol=2e-4, atol=2e-4)

    def test_jvp_x_matches_autodiff(self):
        x = jnp.asarray(rand_vec(5))
        theta = jnp.abs(jnp.asarray(rand_vec(6)))
        v = jnp.asarray(rand_vec(7))
        (got,) = model.ridge_f_jvp_x(x, theta, v, model._DESIGN_J, model._TARGETS_J)
        _, want = jax.jvp(lambda xx: model.ridge_f_reference(xx, theta), (x,), (v,))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_jvp_theta_matches_autodiff(self):
        x = jnp.asarray(rand_vec(8))
        theta = jnp.abs(jnp.asarray(rand_vec(9)))
        v = jnp.asarray(rand_vec(10))
        (got,) = model.ridge_f_jvp_theta(x, theta, v)
        _, want = jax.jvp(lambda tt: model.ridge_f_reference(x, tt), (theta,), (v,))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_design_standardized(self):
        x = model.DESIGN
        np.testing.assert_allclose(x.mean(axis=0), 0.0, atol=1e-6)
        np.testing.assert_allclose(np.linalg.norm(x, axis=0), 1.0, rtol=1e-5)

    def test_oracle_specs_shapes(self):
        specs = model.oracle_specs()
        assert set(specs) >= {"ridge_f", "ridge_f_jvp_x", "ridge_f_jvp_theta"}
        for name, (fn, args) in specs.items():
            outs = fn(*args)
            assert isinstance(outs, tuple), name
            assert all(o.dtype == jnp.float32 for o in outs), name
